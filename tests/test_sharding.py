"""Distribution-layer tests on a small host mesh: spec construction for
every architecture, divisibility guards, and a real sharded forward/train
step on an 2x2 virtual-device mesh (process-local)."""
import os

import numpy as np
import pytest

# NOTE: tests run with the default single CPU device; the spec-construction
# tests need no devices, and the sharded-execution tests use a 1x1 mesh.
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, get_config, get_reduced
from repro.distributed.sharding import (ParallelismConfig, cache_specs,
                                        make_ctx, param_specs)
from repro.models import (forward_decode, forward_full, init_cache,
                          init_params)
from repro.models.cache import cache_spec as cache_sds


def _mesh_1x1():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_specs_structure_matches(arch):
    """Specs pytree has the same structure as params for the FULL config
    (built via eval_shape, no allocation)."""
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    mesh = _mesh_1x1()
    par = ParallelismConfig()
    specs = param_specs(params, cfg, mesh, par)
    jax.tree.map(lambda a, s: None, params, specs,
                 is_leaf=lambda x: isinstance(x, (P, jax.ShapeDtypeStruct)))


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "dbrx-132b", "mamba2-780m"])
def test_param_specs_divisibility(arch):
    """Every sharded dim is divisible by the mesh axes assigned to it."""
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    # fake big mesh via devices? use spec math only: build against a
    # synthetic mesh object with the production shape.
    import repro.launch.mesh  # noqa: F401

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    par = ParallelismConfig()
    specs = param_specs(params, cfg, FakeMesh(), par)

    def check(sds, spec):
        if not isinstance(spec, P):
            return
        for dim, ax in zip(sds.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([FakeMesh.shape[a] for a in axes]))
            assert dim % size == 0, (arch, sds.shape, spec)

    jax.tree.map(check, params, specs,
                 is_leaf=lambda x: isinstance(x, P) or
                 isinstance(x, jax.ShapeDtypeStruct))


def test_cache_specs_prefer_heads_else_seq():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    cfg = get_config("zamba2-1.2b")      # kv=32 divisible by 16
    shapes = jax.eval_shape(lambda: init_cache(cfg, 128, 1024))
    specs = cache_specs(shapes, cfg, FakeMesh(), ParallelismConfig(), 128)
    attn_layers = [i for i, l in enumerate(shapes["layers"]) if "k" in l]
    assert specs["layers"][attn_layers[0]]["k"][2] == "model"
    cfg2 = get_config("qwen3-1.7b")      # kv=8 -> seq sharding
    shapes2 = jax.eval_shape(lambda: init_cache(cfg2, 128, 1024))
    specs2 = cache_specs(shapes2, cfg2, FakeMesh(), ParallelismConfig(), 128)
    assert specs2["layers"][0]["k"][1] == "model"
    assert specs2["layers"][0]["k"][2] is None


def test_sharded_forward_runs_on_mesh():
    """jit with NamedShardings on a 1x1 mesh executes and matches the
    unsharded forward bit-for-bit."""
    import dataclasses
    cfg = dataclasses.replace(get_reduced("qwen3-1.7b"), dtype="float32")
    mesh = _mesh_1x1()
    par = ParallelismConfig()
    params = init_params(jax.random.PRNGKey(0), cfg)
    specs = param_specs(params, cfg, mesh, par)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                       is_leaf=lambda x: isinstance(x, P))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    ctx = make_ctx(mesh, par)

    def fn(p, t):
        logits, _, _ = forward_full(p, cfg, tokens=t, ctx=ctx)
        return logits

    sharded = jax.jit(fn, in_shardings=(psh, NamedSharding(mesh, P())))(
        params, toks)
    plain = fn(params, toks)
    np.testing.assert_allclose(np.asarray(sharded, np.float32),
                               np.asarray(plain, np.float32), atol=2e-2,
                               rtol=2e-2)


def _paged_cfg(kv_heads=8):
    from repro.models import ModelConfig
    return ModelConfig(name="t-paged", family="dense", num_layers=2,
                       d_model=8 * kv_heads, num_heads=kv_heads,
                       num_kv_heads=kv_heads, head_dim=8,
                       d_ff=32, vocab_size=97, dtype="float32")


def test_paged_cache_specs_head_sharding():
    """Paged layout: page arrays shard the kv-head axis when divisible,
    block tables / pos stay replicated (they are host bookkeeping)."""
    from repro.models.cache import init_paged_cache

    class FakeMesh:
        shape = {"data": 1, "model": 2}
    cfg = _paged_cfg(kv_heads=8)
    shapes = jax.eval_shape(lambda: init_paged_cache(cfg, 4, 128, 33, 16))
    specs = cache_specs(shapes, cfg, FakeMesh(), ParallelismConfig(), 4)
    assert specs["block_tables"] == P(None, None)
    assert specs["pos"] == P(None)
    for lyr in specs["layers"]:
        # [num_blocks, page, kv_heads, head_dim] — heads on "model"
        assert lyr["k"] == P(None, None, "model", None)
        assert lyr["v"] == P(None, None, "model", None)


def test_paged_cache_specs_indivisible_heads_replicate():
    """kv heads not divisible by the tp axis -> pages replicate rather
    than shard unevenly (never seq-shard pages: a page is a time slab,
    every shard needs all of it)."""
    from repro.models.cache import init_paged_cache

    class FakeMesh:
        shape = {"data": 1, "model": 2}
    cfg = _paged_cfg(kv_heads=3)
    shapes = jax.eval_shape(lambda: init_paged_cache(cfg, 4, 128, 33, 16))
    specs = cache_specs(shapes, cfg, FakeMesh(), ParallelismConfig(), 4)
    for lyr in specs["layers"]:
        assert lyr["k"] == P(None, None, None, None)


def test_make_host_mesh_sizing():
    """make_host_mesh spans whatever the host exposes: tp defaults to
    local_device_count // data, explicit tp is honored."""
    from repro.launch.mesh import make_host_mesh
    n = jax.local_device_count()
    mesh = make_host_mesh()
    assert dict(mesh.shape) == {"data": 1, "model": n}
    mesh = make_host_mesh(tp=1)
    assert dict(mesh.shape) == {"data": 1, "model": 1}


# ------------------------------------------------- instance mappers
def _req(i, task="chat", l_in=32, l_out=16):
    from repro.core.slo import SLO, Request
    return Request(req_id=i, task_type=task, input_len=l_in, slo=SLO(),
                   output_len=l_out)


def _states(n, **kw):
    from repro.core.policies import InstanceState
    return [InstanceState(instance_id=i, **{k: v[i] for k, v in
                                            kw.items()})
            for i in range(n)]


def test_mapper_round_robin_and_least_loaded():
    from repro.core.policies import make_mapper
    rr = make_mapper("round-robin")
    assert rr.map_batch([_req(i) for i in range(5)], _states(2)) == \
        [0, 1, 0, 1, 0]
    assert rr.map_one(_req(5), _states(2)) == 1     # cursor persists
    ll = make_mapper("least-loaded")
    st = _states(3, queue_depth=[4, 0, 1], active=[0, 2, 0])
    # loads 4/2/1 -> first goes to 2, then 1 and 2 tie -> lowest id
    assert ll.map_batch([_req(0), _req(1), _req(2)], st) == [2, 1, 2]


def test_mapper_slo_affinity_homes_classes():
    from repro.core.policies import make_mapper
    m = make_mapper("slo-affinity")
    reqs = [_req(0, "chat"), _req(1, "code"), _req(2, "chat"),
            _req(3, "summ"), _req(4, "code")]
    out = m.map_batch(reqs, _states(2))
    assert out == [0, 1, 0, 0, 1]    # chat->0, code->1, summ wraps to 0


def test_memory_greedy_matches_eq20_reference():
    """Regression: the shared mapper reproduces the inline Eq. 20 loop
    that SLOAwareScheduler.assign_instances used to carry."""
    from repro.core.policies import MemoryGreedyMapper
    from repro.core.profiler import MemoryModel
    mem = MemoryModel(total_memory=200.0, mu=0.9, sigma_per_token=1.0)
    rng = np.random.default_rng(0)
    reqs = [_req(i, l_in=int(rng.integers(8, 80)),
                 l_out=int(rng.integers(8, 40))) for i in range(40)]
    got = MemoryGreedyMapper(mem).map_batch(reqs, _states(3))

    remaining = [mem.total] * 3                     # inline reference
    want = []
    for r in reqs:
        need = mem.tokens_to_memory(r.input_len + r.planning_output_len())
        tgt = int(np.argmax(remaining))
        if remaining[tgt] < need:
            remaining = [mem.total] * 3
            tgt = 0
        remaining[tgt] -= need
        want.append(tgt)
    assert got == want
    assert len(set(got)) == 3                       # all instances used


def test_scheduler_assign_instances_delegates_to_mapper():
    from repro.core import PAPER_TABLE2
    from repro.core.policies import MemoryGreedyMapper
    from repro.core.profiler import MemoryModel
    from repro.core.scheduler import SLOAwareScheduler
    mem = MemoryModel(total_memory=500.0)
    sched = SLOAwareScheduler(PAPER_TABLE2, num_instances=2, memory=mem)
    reqs = [_req(i, l_in=16 + 13 * i) for i in range(9)]
    buckets = sched.assign_instances(reqs)
    flat = MemoryGreedyMapper(mem).map_batch(reqs, _states(2))
    for inst in range(2):
        assert [r.req_id for r in buckets[inst]] == \
            [r.req_id for r, a in zip(reqs, flat) if a == inst]


def test_mapper_plan_preserves_order():
    """The default plan groups map_batch output without reordering —
    the fleet submits each instance's queue in arrival order."""
    from repro.core.policies import make_mapper
    m = make_mapper("least-loaded")
    reqs = [_req(i) for i in range(7)]
    plan = m.plan(reqs, _states(2))
    assert sorted(i for q in plan for i in q) == list(range(7))
    for q in plan:
        assert q == sorted(q)


def test_mapper_annealed_plan_covers_all():
    from repro.core import PAPER_TABLE2, SAParams
    from repro.core.policies import make_mapper
    m = make_mapper("annealed", model=PAPER_TABLE2, max_batch=4,
                    sa_params=SAParams(iters=40, seed=0))
    reqs = [_req(i, l_in=16 + 9 * i) for i in range(10)]
    plan = m.plan(reqs, _states(2))
    assert sorted(i for q in plan for i in q) == list(range(10))


# ------------------------------------------------- fleet (single device)
def test_fleet_token_parity_single_device():
    """A 2-engine fleet produces the same greedy tokens as one loop on
    the same backlogged trace (no mesh: plain engines, tier-1 safe)."""
    from repro.engine.engine import Engine
    from repro.serving import EngineFleet, ServeLoop

    cfg = _paged_cfg(kv_heads=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    pairs = [(_req(i, l_in=n, l_out=4),
              rng.integers(1, 96, n).astype(np.int32))
             for i, n in enumerate(rng.integers(6, 24, 6).tolist())]

    def run(target):
        streams = target.submit_trace([(r, t) for r, t in pairs])
        target.serve()
        return [s.tokens for s in streams]

    single = run(ServeLoop(Engine(cfg, params, max_slots=2,
                                  max_seq_len=64)))
    fleet = EngineFleet([Engine(cfg, params, max_slots=2, max_seq_len=64)
                         for _ in range(2)], mapper="round-robin")
    assert run(fleet) == single
    m = fleet.metrics.summary()
    assert m["n"] == 6 and m["tokens"] == 24


@pytest.mark.slow
def test_sharded_serving_multidevice():
    """Full sharded-serving verification on a forced 8-device CPU host
    (subprocess: the device count is locked at first jax init).  Covers
    sharded-vs-single logits parity <= 1e-5 (prefill / chunked /
    decode), real head-sharded page placement, engine + fleet token
    parity, pool invariants and CoW under the mesh."""
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run(
        [sys.executable,
         os.path.join(root, "tests", "helpers", "verify_sharding.py")],
        env=dict(os.environ, PYTHONPATH=os.path.join(root, "src")),
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ALL OK" in out.stdout


def test_moe_shard_map_matches_local():
    """MoE FFN with a mesh ctx == MoE FFN without (1x1 mesh)."""
    from repro.models.moe import ShardingCtx, init_moe, moe_ffn
    cfg = get_reduced("deepseek-v2-lite-16b")
    mesh = _mesh_1x1()
    block = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)
    out_local, aux_local = moe_ffn(block, cfg, x, None)
    ctx = ShardingCtx(mesh=mesh, dp_axes=("data",), tp_axis="model")
    out_sm, aux_sm = jax.jit(lambda b, xx: moe_ffn(b, cfg, xx, ctx))(block, x)
    np.testing.assert_allclose(np.asarray(out_sm), np.asarray(out_local),
                               atol=1e-5, rtol=1e-5)
    assert abs(float(aux_sm) - float(aux_local)) < 1e-5
