"""Distribution-layer tests on a small host mesh: spec construction for
every architecture, divisibility guards, and a real sharded forward/train
step on an 2x2 virtual-device mesh (process-local)."""
import os

import numpy as np
import pytest

# NOTE: tests run with the default single CPU device; the spec-construction
# tests need no devices, and the sharded-execution tests use a 1x1 mesh.
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, get_config, get_reduced
from repro.distributed.sharding import (ParallelismConfig, cache_specs,
                                        make_ctx, param_specs)
from repro.models import (forward_decode, forward_full, init_cache,
                          init_params)
from repro.models.cache import cache_spec as cache_sds


def _mesh_1x1():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_specs_structure_matches(arch):
    """Specs pytree has the same structure as params for the FULL config
    (built via eval_shape, no allocation)."""
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    mesh = _mesh_1x1()
    par = ParallelismConfig()
    specs = param_specs(params, cfg, mesh, par)
    jax.tree.map(lambda a, s: None, params, specs,
                 is_leaf=lambda x: isinstance(x, (P, jax.ShapeDtypeStruct)))


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "dbrx-132b", "mamba2-780m"])
def test_param_specs_divisibility(arch):
    """Every sharded dim is divisible by the mesh axes assigned to it."""
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    # fake big mesh via devices? use spec math only: build against a
    # synthetic mesh object with the production shape.
    import repro.launch.mesh  # noqa: F401

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    par = ParallelismConfig()
    specs = param_specs(params, cfg, FakeMesh(), par)

    def check(sds, spec):
        if not isinstance(spec, P):
            return
        for dim, ax in zip(sds.shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([FakeMesh.shape[a] for a in axes]))
            assert dim % size == 0, (arch, sds.shape, spec)

    jax.tree.map(check, params, specs,
                 is_leaf=lambda x: isinstance(x, P) or
                 isinstance(x, jax.ShapeDtypeStruct))


def test_cache_specs_prefer_heads_else_seq():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    cfg = get_config("zamba2-1.2b")      # kv=32 divisible by 16
    shapes = jax.eval_shape(lambda: init_cache(cfg, 128, 1024))
    specs = cache_specs(shapes, cfg, FakeMesh(), ParallelismConfig(), 128)
    attn_layers = [i for i, l in enumerate(shapes["layers"]) if "k" in l]
    assert specs["layers"][attn_layers[0]]["k"][2] == "model"
    cfg2 = get_config("qwen3-1.7b")      # kv=8 -> seq sharding
    shapes2 = jax.eval_shape(lambda: init_cache(cfg2, 128, 1024))
    specs2 = cache_specs(shapes2, cfg2, FakeMesh(), ParallelismConfig(), 128)
    assert specs2["layers"][0]["k"][1] == "model"
    assert specs2["layers"][0]["k"][2] is None


def test_sharded_forward_runs_on_mesh():
    """jit with NamedShardings on a 1x1 mesh executes and matches the
    unsharded forward bit-for-bit."""
    import dataclasses
    cfg = dataclasses.replace(get_reduced("qwen3-1.7b"), dtype="float32")
    mesh = _mesh_1x1()
    par = ParallelismConfig()
    params = init_params(jax.random.PRNGKey(0), cfg)
    specs = param_specs(params, cfg, mesh, par)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                       is_leaf=lambda x: isinstance(x, P))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    ctx = make_ctx(mesh, par)

    def fn(p, t):
        logits, _, _ = forward_full(p, cfg, tokens=t, ctx=ctx)
        return logits

    sharded = jax.jit(fn, in_shardings=(psh, NamedSharding(mesh, P())))(
        params, toks)
    plain = fn(params, toks)
    np.testing.assert_allclose(np.asarray(sharded, np.float32),
                               np.asarray(plain, np.float32), atol=2e-2,
                               rtol=2e-2)


def test_moe_shard_map_matches_local():
    """MoE FFN with a mesh ctx == MoE FFN without (1x1 mesh)."""
    from repro.models.moe import ShardingCtx, init_moe, moe_ffn
    cfg = get_reduced("deepseek-v2-lite-16b")
    mesh = _mesh_1x1()
    block = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)
    out_local, aux_local = moe_ffn(block, cfg, x, None)
    ctx = ShardingCtx(mesh=mesh, dp_axes=("data",), tp_axis="model")
    out_sm, aux_sm = jax.jit(lambda b, xx: moe_ffn(b, cfg, xx, ctx))(block, x)
    np.testing.assert_allclose(np.asarray(out_sm), np.asarray(out_local),
                               atol=1e-5, rtol=1e-5)
    assert abs(float(aux_sm) - float(aux_local)) < 1e-5
