"""The jitted annealer: incremental-Δ scorer vs the full-evaluate oracle
(1e-6 under x64), padding/masking invariance, vmap-multi-instance vs
per-instance equivalence, config validation, and the jax backend of the
online re-anneal policy.  See docs/annealer.md for the contract being
pinned here."""
import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402
from jax.experimental import enable_x64  # noqa: E402

import repro.core.annealing_jax as aj  # noqa: E402
from repro.core import PAPER_TABLE2, SAParams, as_arrays, evaluate  # noqa: E402
from repro.core.annealing_jax import (JaxSAConfig,  # noqa: E402
                                      priority_mapping_jax,
                                      priority_mapping_multi_jax)
from repro.core.objective import (fcfs_schedule,  # noqa: E402
                                  linear_request_coefs,
                                  sorted_by_e2e_schedule)
from repro.data.synthetic import sample_requests  # noqa: E402

# One shared shape/config across tests keeps jit recompilation (the
# dominant cost of this file) to a handful of cache entries.
N, MB = 13, 4
CFG = JaxSAConfig(iters=50, num_chains=2)


def _contended(reqs):
    """Tighten SLOs so schedules mix met and unmet requests."""
    for r in reqs:
        r.slo = dataclasses.replace(
            r.slo,
            e2e=r.slo.e2e * 0.2 if r.slo.e2e else None,
            ttft=r.slo.ttft * 0.02 if r.slo.ttft else None,
            tpot=r.slo.tpot * 0.5 if r.slo.tpot else None)
        r.predicted_output_len = r.output_len
    return reqs


def _arrays(seed, n=N, regime="contended"):
    reqs = sample_requests(n, seed=seed)
    if regime == "contended":
        _contended(reqs)
    else:
        for r in reqs:
            r.predicted_output_len = r.output_len
    return as_arrays(reqs)


def _np_g(arrays, perm_pad, bnd_pad, n):
    perm = np.asarray(perm_pad)[:n]
    bnd = np.asarray(bnd_pad)[:n]
    bid = np.cumsum(bnd.astype(np.int64)) - 1
    return evaluate(arrays, PAPER_TABLE2, perm, bid)


@pytest.fixture(scope="module")
def jitted():
    """Jitted internals at the shared (N, MB) shape."""
    nv = jnp.int32(N)
    return {
        "cand": jax.jit(lambda reqc, perm, bnd, stats, op, i, j:
                        aj._candidate(reqc, perm, bnd, stats, op, i, j,
                                      nv, MB)),
        "apply": jax.jit(aj._apply),
        "agg": jax.jit(lambda stats: aj._agg(stats, MB)),
        "agg_delta": jax.jit(lambda stats, sidx, rows:
                             aj._agg_delta(stats, sidx, rows, MB)),
        "build": jax.jit(lambda reqc, perm, bnd:
                         aj._build_stats(reqc, perm, bnd, MB)),
        "eval_g": jax.jit(aj._eval_g),
    }


@pytest.mark.parametrize("regime", ["contended", "loose"])
def test_incremental_scorer_matches_oracle_to_1e6(jitted, regime):
    """The contract: after any chain of valid moves, the incremental
    stats score the schedule to 1e-6 of BOTH the in-jit full objective
    and the numpy ``evaluate`` oracle (exact in x64), the met counts
    agree exactly, and no row goes stale (rebuild-from-scratch parity).
    """
    with enable_x64():
        for seed in (0, 1):
            arrays = _arrays(seed, regime=regime)
            reqc = aj._pack(arrays, PAPER_TABLE2, aj._pad_len(N))
            assert reqc.dtype == jnp.float64
            _, perm, bnd = aj._starts(reqc, jnp.int32(N), MB)
            stats = jitted["build"](reqc, perm, bnd)
            rng = np.random.default_rng(seed)
            applied = 0
            for _ in range(60):
                op = jnp.int32(rng.integers(0, 3))
                i = jnp.int32(rng.integers(1, N))
                j = jnp.int32(rng.integers(0, N))
                ok, _, upd = jitted["cand"](reqc, perm, bnd, stats,
                                            op, i, j)
                g_delta, met_delta = jitted["agg_delta"](stats, upd[4],
                                                         upd[5])
                if not bool(ok):
                    continue
                applied += 1
                perm, bnd, stats = jitted["apply"](perm, bnd, stats, upd,
                                                   jnp.bool_(True))
                g_inc, met_inc = jitted["agg"](stats)
                g_full, met_full = jitted["eval_g"](reqc, perm, bnd)
                ev = _np_g(arrays, perm, bnd, N)
                scale = max(ev.G, 1e-9)
                assert abs(float(g_delta) - float(g_inc)) <= 1e-12
                assert int(met_delta) == int(met_inc)
                assert abs(float(g_inc) - float(g_full)) <= 1e-9 * scale
                assert abs(float(g_inc) - ev.G) <= 1e-6 * scale
                assert int(met_inc) == int(met_full) == ev.n_met
                fresh = jitted["build"](reqc, perm, bnd)
                for got, want in zip(stats, fresh):
                    np.testing.assert_allclose(np.asarray(got),
                                               np.asarray(want))
            assert applied > 20          # the move stream was exercised


def test_scorer_padding_invariance():
    """Masked padding must not change the objective: the same instance
    packed at two pad lengths scores identically (and equals numpy)."""
    with enable_x64():
        arrays = _arrays(3)
        p0, b0 = fcfs_schedule(N, MB)
        for pad in (16, 32):
            reqc = aj._pack(arrays, PAPER_TABLE2, pad)
            perm = jnp.asarray(
                np.concatenate([p0, np.arange(N, pad)]), jnp.int32)
            bnd = jnp.asarray(np.concatenate(
                [b0 != np.concatenate([[-1], b0[:-1]]),
                 np.ones(pad - N, bool)]))
            stats = aj._build_stats(reqc, perm, bnd, MB)
            g, met = aj._agg(stats, MB)
            g_full, met_full = aj._eval_g(reqc, perm, bnd)
            ev = _np_g(arrays, perm, bnd, N)
            assert abs(float(g) - ev.G) <= 1e-6 * max(ev.G, 1e-9)
            assert abs(float(g_full) - ev.G) <= 1e-6 * max(ev.G, 1e-9)
            assert int(met) == int(met_full) == ev.n_met


def test_linear_request_coefs_shared_contract():
    """The packed coefficient matrix is exactly the Python backend's
    linear-in-b terms (one contract, two consumers)."""
    arrays = _arrays(5)
    coefs = linear_request_coefs(arrays, PAPER_TABLE2)
    reqc = np.asarray(aj._pack(arrays, PAPER_TABLE2, aj._pad_len(N)))
    for col, key in ((aj._EA, "eA"), (aj._EC, "eC"), (aj._PA, "pA"),
                     (aj._PC, "pC"), (aj._TA, "tA"), (aj._TC, "tC")):
        np.testing.assert_allclose(reqc[:N, col], coefs[key], rtol=1e-6)
    assert (reqc[N:, aj._VALID] == 0).all()
    assert (reqc[:N, aj._VALID] == 1).all()


def test_incremental_anneal_matches_full_anneal_invariants():
    """End to end, both scoring paths return valid schedules that never
    lose to either Algorithm 1 starting solution, and report G on the
    oracle scale."""
    for seed in (0, 1):
        arrays = _arrays(seed, n=16)
        p0, b0 = fcfs_schedule(16, MB)
        ps, bs = sorted_by_e2e_schedule(arrays, PAPER_TABLE2, MB)
        g_start = max(evaluate(arrays, PAPER_TABLE2, p0, b0).G,
                      evaluate(arrays, PAPER_TABLE2, ps, bs).G)
        for inc in (True, False):
            perm, bid, g = priority_mapping_jax(
                arrays, PAPER_TABLE2, MB, CFG, seed=seed, incremental=inc)
            ev = evaluate(arrays, PAPER_TABLE2, perm, bid)
            assert sorted(perm.tolist()) == list(range(16))
            assert np.bincount(bid).max() <= MB
            assert ev.G >= g_start * (1 - 1e-5)
            assert abs(ev.G - g) <= 2e-3 * max(g, 1e-12)  # f32 report


def test_vmap_multi_matches_per_instance_chains():
    """One vmapped (instances × chains) program must equal running each
    padded instance through the single-instance chain runner with the
    same per-instance keys — the padding/masking does the work of the
    per-instance loop."""
    sizes = (9, 16, 5)
    arrays_list = [_arrays(100 + k, n=n) for k, n in enumerate(sizes)]
    multi = priority_mapping_multi_jax(arrays_list, PAPER_TABLE2, MB, CFG,
                                       seed=7)
    pad = aj._pad_len(max(sizes))
    base = jax.random.PRNGKey(7)
    for i, (arrays, n) in enumerate(zip(arrays_list, sizes)):
        reqc = aj._pack(arrays, PAPER_TABLE2, pad)
        keys = jax.random.split(jax.random.fold_in(base, i),
                                CFG.num_chains)
        perms, bnds, fs = aj._run_chains(keys, reqc, jnp.int32(n), MB,
                                         CFG, True)
        best = int(jnp.argmax(fs))
        perm, bid = aj._extract(perms[best], bnds[best], n)
        m_perm, m_bid, m_g = multi[i]
        np.testing.assert_array_equal(m_perm, perm)
        np.testing.assert_array_equal(m_bid, bid)
        assert m_g == pytest.approx(float(fs[best]), rel=1e-6)
        # and the result is a valid schedule for the instance
        assert sorted(m_perm.tolist()) == list(range(n))
        assert np.bincount(m_bid).max() <= MB


def test_multi_handles_empty_and_ragged():
    arrays_list = [_arrays(0, n=6), as_arrays([]), _arrays(1, n=16)]
    out = priority_mapping_multi_jax(arrays_list, PAPER_TABLE2, MB, CFG,
                                     seed=0)
    assert len(out) == 3
    assert out[1][0].size == 0 and out[1][2] == 0.0
    for (perm, bid, _), n in zip((out[0], out[2]), (6, 16)):
        assert sorted(perm.tolist()) == list(range(n))


def test_jax_config_and_args_validated():
    with pytest.raises(ValueError, match="num_chains"):
        JaxSAConfig(num_chains=0)
    with pytest.raises(ValueError, match="iters"):
        JaxSAConfig(iters=0)
    with pytest.raises(ValueError, match="tau"):
        JaxSAConfig(tau=1.0)
    with pytest.raises(ValueError, match="temperatures"):
        JaxSAConfig(T0=0.0)
    with pytest.raises(ValueError, match="zero proposals"):
        JaxSAConfig(T0=100.0, T_thres=200.0)
    with pytest.raises(ValueError, match="max_batch"):
        priority_mapping_jax(_arrays(0), PAPER_TABLE2, 0, CFG)
    with pytest.raises(ValueError, match="max_batch"):
        priority_mapping_multi_jax([_arrays(0)], PAPER_TABLE2, -1, CFG)


def test_config_from_sa_params_preserves_budget():
    """SAParams.iters is a TOTAL proposal budget under the default
    budget_mode="global"; the jitted iters-per-level must not inflate it
    by the level count."""
    p = SAParams(iters=100)                      # global budget
    cfg = aj.config_from_sa_params(p)
    total = cfg.n_levels * cfg.iters
    assert total <= 3 * p.iters                  # same order, not ~63x
    assert cfg.iters >= 1
    plvl = SAParams(iters=100, budget_mode="per_level")
    assert aj.config_from_sa_params(plvl).iters == 100
    with pytest.raises(ValueError, match="ablation"):
        aj.config_from_sa_params(SAParams(moves=(2,)))
    with pytest.raises(ValueError, match="ablation"):
        aj.config_from_sa_params(SAParams(acceptance="greedy"))
    # the scheduler front end validates at construction too
    from repro.core import SLOAwareScheduler
    with pytest.raises(ValueError, match="ablation"):
        SLOAwareScheduler(PAPER_TABLE2, use_jax=True,
                          sa_params=SAParams(acceptance="greedy"))


def test_reanneal_policy_jax_backend():
    """The v2 policy stack runs on the jitted annealer backend: the
    ``slo-reanneal:jax`` registry key drives the event core end to end
    and admits a permutation of the pending queue."""
    from repro.core.online import simulate_online
    from repro.core.policies import make

    rng = np.random.default_rng(3)
    reqs = sample_requests(14, seed=8)
    t = 0.0
    for r in reqs:
        t += rng.exponential(0.3)
        r.arrival_time = t
        r.predicted_output_len = r.output_len
    pol = make("slo-reanneal:jax", model=PAPER_TABLE2, max_batch=MB,
               sa_params=SAParams(seed=0, iters=CFG.iters))
    assert pol.backend == "jax"
    res = simulate_online(reqs, PAPER_TABLE2, MB, pol)
    assert res.n == 14
    with pytest.raises(ValueError, match="backend"):
        make("slo-reanneal", model=PAPER_TABLE2, max_batch=MB,
             backend="tpu")
    # jit-unsupported ablation params fail at construction, not on the
    # first admission event mid-run
    with pytest.raises(ValueError, match="ablation"):
        make("slo-reanneal:jax", model=PAPER_TABLE2, max_batch=MB,
             sa_params=SAParams(acceptance="greedy"))


def test_property_scorer_parity_random_schedules(jitted):
    """Hypothesis sweep (optional dep): arbitrary valid boundary layouts
    and permutations — incremental stats == full objective == numpy."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(seed=st.integers(0, 2**31 - 1),
               regime=st.sampled_from(["contended", "loose"]))
    def run(seed, regime):
        rng = np.random.default_rng(seed)
        arrays = _arrays(seed % 7, regime=regime)
        # random permutation + random boundaries respecting max_batch
        p = rng.permutation(N)
        cuts, pos = [True], 1
        run_len = 1
        while pos < N:
            new = bool(rng.integers(0, 2)) or run_len >= MB
            cuts.append(new)
            run_len = 1 if new else run_len + 1
            pos += 1
        pad = aj._pad_len(N)
        reqc = aj._pack(arrays, PAPER_TABLE2, pad)
        perm = jnp.asarray(np.concatenate([p, np.arange(N, pad)]),
                           jnp.int32)
        bnd = jnp.asarray(np.concatenate(
            [np.asarray(cuts), np.ones(pad - N, bool)]))
        stats = jitted["build"](reqc, perm, bnd)
        g, met = jitted["agg"](stats)
        g_full, met_full = jitted["eval_g"](reqc, perm, bnd)
        ev = _np_g(arrays, perm, bnd, N)
        scale = max(ev.G, 1e-9)
        assert abs(float(g) - float(g_full)) <= 2e-5 * scale    # f32
        assert abs(float(g) - ev.G) <= 2e-5 * scale
        assert int(met) == int(met_full) == ev.n_met

    run()
