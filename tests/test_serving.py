"""Streaming serving loop: overlap/sync token parity, measured-vs-engine
metric agreement, wall-clock arrival pacing, pow-2 dispatch bucketing,
preemption, rejection, and summary sanity."""
import time

import jax
import numpy as np
import pytest

from repro.core.latency_model import LinearLatencyModel
from repro.core.slo import SLO, Request
from repro.data.synthetic import sample_serve_workload
from repro.engine.engine import Engine
from repro.models import ModelConfig, init_params
from repro.serving import (ServeLoop, ServingMetrics, TokenStream,
                           UnsupportedDisciplineError)

CFG = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                  dtype="float32")


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _engine(params, **kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_seq_len", 128)
    return Engine(CFG, params, **kw)


def _prompts(n, seed=0, lo=8, hi=40):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 128, int(rng.integers(lo, hi))).astype(np.int32)
            for _ in range(n)]


def _run(params, overlap, paged, prompts, max_new=5, rate_gap=0.002,
         policy="fcfs", **loop_kw):
    eng = _engine(params, paged=paged,
                  num_blocks=64 if paged else None)
    loop = ServeLoop(eng, policy, overlap=overlap, **loop_kw)
    loop.start(warm_lengths=[len(p) for p in prompts])
    streams = [loop.submit(p, max_new_tokens=max_new,
                           slo=SLO(ttft=100.0, tpot=10.0),
                           arrival_time=i * rate_gap)
               for i, p in enumerate(prompts)]
    res = loop.serve()
    return loop, streams, res


@pytest.mark.parametrize("paged", [False, True])
def test_overlap_sync_token_parity(params, paged):
    """Greedy decode through the overlapped one-step-lookahead loop must
    produce exactly the tokens of the synchronous reference loop."""
    prompts = _prompts(7, seed=3)
    _, s_sync, _ = _run(params, overlap=False, paged=paged, prompts=prompts)
    _, s_over, _ = _run(params, overlap=True, paged=paged, prompts=prompts)
    for a, b in zip(s_sync, s_over):
        assert a.tokens == b.tokens
        assert len(a.tokens) == 5


def test_stream_metrics_agree_with_engine_accounting(params):
    """Measured TTFT/e2e from the token streams' wall-clock stamps must
    agree with the engine's own per-request accounting (the loop syncs
    the engine clock to the wall each tick; prefill advances it by the
    measured step time, so the two differ by at most one tick)."""
    loop, streams, res = _run(params, overlap=True, paged=True,
                              prompts=_prompts(6, seed=4))
    for st in streams:
        eng_row = res[st.req_id]
        assert st.ttft() == pytest.approx(eng_row["ttft"], abs=0.05)
        assert st.e2e() == pytest.approx(eng_row["e2e"], abs=0.05)
        assert st.tokens == eng_row["tokens"]
        # tbt gaps sum to the decode span of the measured e2e
        assert sum(st.tbts()) == pytest.approx(st.e2e() - st.ttft(),
                                               abs=1e-9)


def test_arrival_pacing_on_wall_clock(params):
    """A future arrival must not be admitted before its instant passes
    on the wall clock, and waiting counts from the arrival instant."""
    eng = _engine(params)
    loop = ServeLoop(eng, "fcfs")
    loop.start(warm_lengths=[16])
    prompts = _prompts(2, seed=5, lo=16, hi=17)
    t_arr = 0.15
    early = loop.submit(prompts[0], max_new_tokens=3, arrival_time=0.0)
    late = loop.submit(prompts[1], max_new_tokens=3, arrival_time=t_arr)
    loop.serve()
    assert early.events[0].t < t_arr
    assert late.submit_time == pytest.approx(t_arr, abs=0.06)
    assert late.events[0].t >= t_arr


def test_dispatch_widths_are_pow2_buckets(params):
    """Paged + bucketed dispatch must round batch width to powers of two
    covering the highest occupied slot, never the full pool when few
    slots are live."""
    loop, _, _ = _run(params, overlap=True, paged=True,
                      prompts=_prompts(3, seed=6), max_new=6,
                      rate_gap=0.0)
    widths = {g.dispatch_width for g in loop.metrics.gauges
              if g.dispatch_width > 0}
    assert widths, "no decode rounds dispatched"
    assert all(w & (w - 1) == 0 for w in widths)      # pow-2
    assert all(w <= 4 for w in widths)
    # 3 requests on 4 slots, lowest-slot-first: width never exceeds 4
    # and a single-request tail dispatches at width 1 or 2, not 4
    loop1, _, _ = _run(params, overlap=True, paged=True,
                       prompts=_prompts(1, seed=7), max_new=6)
    assert {g.dispatch_width for g in loop1.metrics.gauges
            if g.dispatch_width > 0} == {1}


def test_preemptive_policy_completes_all(params):
    """slo-preempt inside the serving loop: evictions re-queue the
    victim (KV recomputed on re-admission) and every request still
    finishes with its full token budget."""
    model = LinearLatencyModel(alpha_p=1e-6, beta_p=1e-4, gamma_p=1e-5,
                               delta_p=2e-3, alpha_d=1e-7, beta_d=1e-4,
                               gamma_d=1e-6, delta_d=1e-3)
    eng = _engine(params, max_slots=2, paged=True, num_blocks=64)
    loop = ServeLoop(eng, "slo-preempt", model=model)
    loop.start()
    streams = []
    # long loose-deadline jobs first, tight interactive arrivals behind
    for i, p in enumerate(_prompts(2, seed=8, lo=24, hi=40)):
        streams.append(loop.submit(p, max_new_tokens=24, slo=SLO(e2e=60.0),
                                   task_type="code", arrival_time=0.0))
    for i, p in enumerate(_prompts(3, seed=9, lo=8, hi=16)):
        streams.append(loop.submit(p, max_new_tokens=3,
                                   slo=SLO(ttft=0.03, tpot=0.05),
                                   arrival_time=0.02 + i * 0.01))
    res = loop.serve()
    assert len(res) == 5
    for st in streams:
        assert st.done and st.error is None
    budgets = [24, 24, 3, 3, 3]
    for st, want in zip(streams, budgets):
        assert len(st.tokens) == want


def test_unservable_request_is_rejected(params):
    """Prompts that cannot fit (length or lifetime KV footprint) fail
    their stream instead of wedging the loop."""
    eng = _engine(params, paged=True, num_blocks=8, block_size=16)
    loop = ServeLoop(eng, "fcfs")
    loop.start()
    ok = loop.submit(_prompts(1, seed=10, lo=16, hi=17)[0],
                     max_new_tokens=4)
    big = loop.submit(np.zeros(100, np.int32), max_new_tokens=60)
    loop.serve()
    assert ok.done and ok.error is None and len(ok.tokens) == 4
    assert big.error is not None and big.tokens == []
    s = loop.metrics.summary()
    assert s["rejected"] == 1 and s["n"] == 1


def test_summary_and_gauges_sanity(params):
    loop, streams, _ = _run(params, overlap=True, paged=True,
                            prompts=_prompts(6, seed=11))
    s = loop.metrics.summary()
    assert s["n"] == 6 and s["tokens"] == 30
    assert 0.0 <= s["attainment"] <= 1.0
    assert s["overlap_frac"] > 0.0          # lookahead actually engaged
    assert s["tokens_per_s"] > 0
    assert s["queue_depth_max"] >= 0
    rows = loop.metrics.rows()
    assert rows and rows[0][0] == "serve_summary"


def test_chunked_discipline_streams_end_to_end(params):
    """Chunked prefill streams natively (chunk-as-tick): every request
    completes with its full budget and the tokens equal the stalling
    run's — chunk boundaries change timing, not greedy content (each
    chunk attends exactly the same prefix KV)."""
    prompts = _prompts(5, seed=20, lo=20, hi=40)
    _, s_stall, _ = _run(params, overlap=True, paged=True, prompts=prompts)
    loop, s_chunk, res = _run(params, overlap=True, paged=True,
                              prompts=prompts, discipline="chunked:16")
    assert loop.disc.chunk_size == 16
    for a, b in zip(s_stall, s_chunk):
        assert b.done and b.error is None
        assert a.tokens == b.tokens and len(b.tokens) == 5
    assert len(res) == len(prompts)
    # at least one prompt spans several chunks: some tick carried
    # prefill work while slots were still mid-prefill afterwards
    gauges = loop.metrics.gauges
    assert sum(g.prefill_tokens for g in gauges) >= \
        sum(len(p) for p in prompts)
    assert any(g.prefilling > 0 for g in gauges)


def test_chunked_engine_default_adopted_and_dynamic_chunk_streams(params):
    """A chunk-configured engine streams under its own default, and
    dynamic-chunk (which carries AdaptiveChunkedPrefill) is executed —
    not refused — with every request completing."""
    from repro.core import PAPER_TABLE2
    eng = _engine(params, chunked_prefill=16)
    loop = ServeLoop(eng, "fcfs")
    assert loop.disc.chunk_size == 16
    eng2 = _engine(params, paged=True, num_blocks=64)
    loop2 = ServeLoop(eng2, "dynamic-chunk", model=PAPER_TABLE2)
    assert loop2.disc is loop2.pol.discipline    # identity: retune flows
    loop2.start()
    streams = [loop2.submit(p, max_new_tokens=4,
                            slo=SLO(ttft=100.0, tpot=10.0))
               for p in _prompts(4, seed=21, lo=20, hi=40)]
    loop2.serve()
    for st in streams:
        assert st.done and st.error is None and len(st.tokens) == 4


def test_chunked_on_mla_engine_raises_typed_error(params):
    """The one remaining unsupported combination: MLA archs have no
    chunked forward path, so a chunked discipline on an MLA engine is a
    configuration error — typed, catchable, at construction."""
    from repro.models.config import MLAConfig
    mla_cfg = ModelConfig(name="tiny-mla", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=4,
                          d_ff=128, vocab_size=128, dtype="float32",
                          mla=MLAConfig(kv_lora_rank=16, q_lora_rank=0,
                                        qk_nope_head_dim=8,
                                        qk_rope_head_dim=8, v_head_dim=8))
    mla_params = init_params(jax.random.PRNGKey(1), mla_cfg)
    eng = Engine(mla_cfg, mla_params, max_slots=2, max_seq_len=128)
    with pytest.raises(UnsupportedDisciplineError):
        ServeLoop(eng, "fcfs", discipline="chunked:16")
    # NotImplementedError subclassing keeps older handlers working
    with pytest.raises(NotImplementedError):
        ServeLoop(Engine(mla_cfg, mla_params, max_slots=2,
                         max_seq_len=128), "fcfs", discipline="chunked:16")


def test_stream_iteration_from_other_thread(params):
    """The blocking stream iterator drains tokens concurrently with the
    serving thread."""
    import threading
    eng = _engine(params)
    loop = ServeLoop(eng, "fcfs")
    loop.start(warm_lengths=[16])
    seen = []
    st = loop.submit(_prompts(1, seed=12, lo=16, hi=17)[0],
                     max_new_tokens=4)
    reader = threading.Thread(
        target=lambda: seen.extend(ev.token for ev in st))
    reader.start()
    loop.serve()
    reader.join(timeout=5)
    assert not reader.is_alive()
    assert seen == st.tokens and len(seen) == 4


def test_serve_workload_trace_replay(params):
    """sample_serve_workload pairs replay through submit_trace; measured
    wall attainment lands in the engine-style results."""
    pairs = sample_serve_workload(4, CFG.vocab_size, seed=13,
                                  arrival_rate=200.0, in_range=(8, 24),
                                  out_range=(3, 6))
    eng = _engine(params)
    loop = ServeLoop(eng, "fcfs")
    loop.start(warm_lengths=[len(p) for _, p in pairs])
    loop.submit_trace(pairs)
    res = loop.serve()
    assert len(res) == 4
    for v in res.values():
        assert "met_wall" in v and v["tokens"]
