"""Hypothesis property tests on system invariants.

``hypothesis`` is an *optional* dev dependency (see pyproject.toml's
``dev`` extra); the whole module is skipped when it is absent so the
tier-1 suite collects everywhere.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dev dependency (pip install hypothesis)")
from hypothesis import given, settings, strategies as st

from repro.core import (PAPER_TABLE2, SAParams, as_arrays, evaluate,
                        fcfs_schedule, priority_mapping)
from repro.core.latency_model import LinearLatencyModel, fit
from repro.core.slo import SLO, Request


def _requests(draw, n):
    reqs = []
    for i in range(n):
        kind = draw(st.booleans())
        li = draw(st.integers(8, 1500))
        lo = draw(st.integers(1, 800))
        if kind:
            slo = SLO(e2e=draw(st.floats(0.5, 100.0)))
        else:
            slo = SLO(ttft=draw(st.floats(0.1, 30.0)),
                      tpot=draw(st.floats(0.005, 0.5)))
        reqs.append(Request(i, "code" if kind else "chat", li, slo,
                            output_len=lo))
    return reqs


@st.composite
def request_sets(draw, max_n=12):
    n = draw(st.integers(2, max_n))
    return _requests(draw, n)


@settings(max_examples=30, deadline=None)
@given(request_sets(), st.integers(1, 6), st.integers(0, 10))
def test_sa_output_is_valid_schedule(reqs, max_batch, seed):
    """SA returns a permutation with batch sizes within the limit, and its
    G is never below the better of the two starting solutions."""
    arrays = as_arrays(reqs)
    n = len(reqs)
    res = priority_mapping(arrays, PAPER_TABLE2, max_batch,
                           SAParams(seed=seed))
    assert sorted(res.perm.tolist()) == list(range(n))
    sizes = np.bincount(res.batch_id)
    assert sizes.max() <= max_batch
    assert (np.diff(res.batch_id) >= 0).all()      # monotone batch ids
    # G consistency: reported == recomputed
    ev = evaluate(arrays, PAPER_TABLE2, res.perm, res.batch_id)
    assert abs(ev.G - res.G) < 1e-12
    p0, b0 = fcfs_schedule(n, max_batch)
    g0 = evaluate(arrays, PAPER_TABLE2, p0, b0).G
    assert res.G >= g0 - 1e-12


@settings(max_examples=30, deadline=None)
@given(request_sets(max_n=10), st.integers(1, 4))
def test_evaluate_invariants(reqs, max_batch):
    """e2e = exec + wait; waits are non-decreasing across batches; G equals
    n_met / sum(e2e)."""
    arrays = as_arrays(reqs)
    n = len(reqs)
    perm, bid = fcfs_schedule(n, max_batch)
    ev = evaluate(arrays, PAPER_TABLE2, perm, bid)
    assert ev.e2e.min() > 0
    assert ev.total_latency == 0 or \
        abs(ev.G * ev.total_latency - ev.n_met) < 1e-6
    # wait monotonicity: first member of each batch has wait >= previous
    waits = ev.e2e - (ev.ttft - PAPER_TABLE2.prefill_time(
        np.bincount(bid)[bid], arrays["input_len"])) \
        if False else None
    # TTFT <= e2e always
    assert (ev.ttft <= ev.e2e + 1e-9).all()
    # TPOT positive
    assert (ev.tpot > 0).all()


@settings(max_examples=25, deadline=None)
@given(request_sets(max_n=14), st.integers(1, 5), st.integers(0, 6))
def test_incremental_delta_matches_full_evaluate(reqs, max_batch, seed):
    """The incremental-ΔG evaluator agrees with the full ``evaluate``
    oracle (G to 1e-9, n_met exactly) across random accepted/rejected move
    sequences, and its structural application matches ``apply_move``."""
    import random

    from repro.core import IncrementalEvaluator
    from repro.core.annealing import (_to_arrays, _to_batches, apply_move,
                                      propose_move)
    arrays = as_arrays(reqs)
    n = len(reqs)
    perm, bid = fcfs_schedule(n, max_batch)
    inc = IncrementalEvaluator(arrays, PAPER_TABLE2, _to_batches(perm, bid))
    rng = random.Random(seed)
    for _ in range(40):
        move = propose_move(inc.batches, max_batch, rng)
        if move is None:
            continue
        g, n_met, staged = inc.preview(move)
        cand = apply_move(inc.batches, move)
        assert cand == staged[0]
        ev = evaluate(arrays, PAPER_TABLE2, *_to_arrays(cand))
        assert abs(ev.G - g) <= 1e-9 * max(1.0, abs(ev.G))
        assert ev.n_met == n_met
        if rng.random() < 0.5:
            inc.commit(staged)
    # committed state stays consistent with the oracle
    ev = evaluate(arrays, PAPER_TABLE2, *_to_arrays(inc.batches))
    assert abs(ev.G - inc.G) <= 1e-9 * max(1.0, abs(ev.G))


@settings(max_examples=20, deadline=None)
@given(st.floats(1e-6, 1e-2), st.floats(1e-6, 1e-2), st.floats(1e-6, 1e-2),
       st.floats(1e-6, 1e-1))
def test_fit_identifiability(a, bb, g, d):
    """OLS recovers arbitrary positive coefficients from noiseless data."""
    true = LinearLatencyModel(a, bb, g, d, a / 10, bb / 10, g / 10, d / 10)
    pre = [(b, l, true.prefill_time(b, l))
           for b in (1, 2, 4, 8) for l in (64, 256, 1024, 1600)]
    dec = [(b, l, true.per_token_decode_time(b, l))
           for b in (1, 2, 4, 8) for l in (64, 256, 1024, 1600)]
    m = fit(pre, dec)
    np.testing.assert_allclose(m.as_tuple(), true.as_tuple(), rtol=1e-5,
                               atol=1e-12)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 32), st.integers(0, 500), st.integers(1, 300))
def test_decode_time_closed_form(b, li, lo):
    m = PAPER_TABLE2
    explicit = sum(m.per_token_decode_time(b, li + k)
                   for k in range(1, lo + 1))
    assert abs(m.decode_time(b, li, lo) - explicit) < 1e-9 * max(explicit, 1)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 64), st.integers(1, 200))
def test_ring_write_invariant(L, s):
    """After writing s tokens into a ring of length L, slot t%L holds
    token t for every kept token."""
    import jax.numpy as jnp
    from repro.models.cache import _ring_write
    buf = jnp.full((1, L, 1), -1.0)
    vals = jnp.arange(s, dtype=jnp.float32).reshape(1, s, 1)
    out = np.asarray(_ring_write(buf, vals))[0, :, 0]
    lo = max(0, s - L)
    for t in range(lo, s):
        assert out[t % L] == t


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(1, 10), min_size=2, max_size=6).filter(
    lambda cs: 4 <= sum(cs) <= 32))
def test_chunked_prefill_any_split(chunks):
    """forward_chunk over ANY chunk split equals whole-sequence prefill."""
    import jax
    import jax.numpy as jnp
    from repro.models import (ModelConfig, forward_full, init_cache,
                              init_params)
    from repro.models.model import forward_chunk
    cfg = ModelConfig(name="pp", family="dense", num_layers=2, d_model=32,
                      num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=53,
                      dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    n = sum(chunks)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, n), 0, 53)
    ca = init_cache(cfg, 1, 64)
    la, ca, _ = forward_full(params, cfg, tokens=toks, cache=ca)
    cb = init_cache(cfg, 1, 64)
    i = 0
    for c in chunks:
        lb, cb = forward_chunk(params, cfg, tokens=toks[:, i:i + c],
                               cache=cb)
        i += c
    assert float(jnp.max(jnp.abs(lb[:, 0] - la[:, -1]))) < 1e-3
    assert int(cb["pos"][0]) == n
