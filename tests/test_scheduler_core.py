"""Unit tests for the paper's core: objective G, Algorithm 1, exhaustive
oracle, latency model, and the worked examples from Figs. 3-5."""
import numpy as np
import pytest

from repro.core import (PAPER_TABLE2, SAParams, as_arrays, evaluate,
                        exhaustive_search, fcfs_schedule, priority_mapping)
from repro.core.latency_model import LinearLatencyModel, fit
from repro.core.slo import SLO, Request
from repro.data.synthetic import sample_requests


def _const_model(exec_s_per_token: float = 0.0):
    """A model where exec time is delta_p + delta_d * l_o (b-independent)."""
    return LinearLatencyModel(0, 0, 0, 0.0, 0, 0, 0, exec_s_per_token)


def make_requests(execs, slos):
    """Requests with e2e SLOs whose exec time ~= execs[i] (via delta_d)."""
    reqs = []
    for i, (e, s) in enumerate(zip(execs, slos)):
        reqs.append(Request(req_id=i, task_type="code", input_len=1,
                            output_len=1000, slo=SLO(e2e=s)))
    return reqs


class TestFig3Example:
    """Paper Fig. 3: three jobs, exec 300/500/800ms, SLOs 800/500/1800ms."""

    def setup_method(self):
        # model: exec = delta_d * l_o ; choose l_o to give 0.3/0.5/0.8 s
        self.model = LinearLatencyModel(0, 0, 0, 0, 0, 0, 0, 1e-3)
        self.reqs = [
            Request(0, "code", 1, SLO(e2e=0.8), output_len=300),
            Request(1, "code", 1, SLO(e2e=0.5), output_len=500),
            Request(2, "code", 1, SLO(e2e=1.8), output_len=800),
        ]
        self.arrays = as_arrays(self.reqs)

    def test_exec_order_by_time_misses_job2(self):
        # (B): order 1,2,3 -> job2 finishes at 0.8 > 0.5 SLO
        ev = evaluate(self.arrays, self.model, np.array([0, 1, 2]),
                      np.array([0, 1, 2]))
        assert ev.n_met == 2
        assert ev.met[1] == False  # noqa: E712

    def test_slo_aware_order_meets_all(self):
        # (C): job2 first -> all meet SLOs, G improves
        ev = evaluate(self.arrays, self.model, np.array([1, 0, 2]),
                      np.array([0, 1, 2]))
        assert ev.n_met == 3
        ev_b = evaluate(self.arrays, self.model, np.array([0, 1, 2]),
                        np.array([0, 1, 2]))
        assert ev.G > ev_b.G

    def test_sa_finds_the_slo_aware_order(self):
        res = priority_mapping(self.arrays, self.model, 1, SAParams(seed=0))
        ev = evaluate(self.arrays, self.model, res.perm, res.batch_id)
        assert ev.n_met == 3


def test_wait_times_accumulate_across_batches():
    model = LinearLatencyModel(0, 0, 0, 1.0, 0, 0, 0, 0)  # 1 s prefill
    reqs = [Request(i, "code", 1, SLO(e2e=100), output_len=1)
            for i in range(4)]
    arrays = as_arrays(reqs)
    ev = evaluate(arrays, model, np.arange(4), np.array([0, 0, 1, 1]))
    # batch 0 requests wait 0, batch 1 requests wait 1 s
    np.testing.assert_allclose(ev.e2e[:2], 1.0)
    np.testing.assert_allclose(ev.e2e[2:], 2.0)


def test_batch_size_affects_exec_time():
    model = LinearLatencyModel(0, 1.0, 0, 0, 0, 0, 0, 0)  # beta_p = 1s/req
    reqs = [Request(i, "code", 1, SLO(e2e=100), output_len=1)
            for i in range(4)]
    arrays = as_arrays(reqs)
    ev1 = evaluate(arrays, model, np.arange(4), np.arange(4))     # b=1 each
    ev4 = evaluate(arrays, model, np.arange(4), np.zeros(4, int))  # b=4
    assert ev1.e2e[0] == pytest.approx(1.0)
    assert ev4.e2e[0] == pytest.approx(4.0)  # slower per request when batched


def test_ttft_tpot_slo_class():
    model = LinearLatencyModel(0, 0, 0, 0.5, 0, 0, 0, 0.01)
    ok = Request(0, "chat", 100, SLO(ttft=1.0, tpot=0.05), output_len=10)
    bad_ttft = Request(1, "chat", 100, SLO(ttft=0.1, tpot=0.05),
                       output_len=10)
    bad_tpot = Request(2, "chat", 100, SLO(ttft=1.0, tpot=0.005),
                       output_len=10)
    arrays = as_arrays([ok, bad_ttft, bad_tpot])
    ev = evaluate(arrays, model, np.arange(3), np.arange(3))
    assert list(ev.met) == [True, False, False]


def test_sa_matches_exhaustive_small():
    """Paper: <=1.0% degradation vs exhaustive.  Holds for CONTENDED
    workloads — when the e2e-sorted start meets every SLO, Algorithm 1's
    line-7 early exit returns it without optimizing G further (faithful
    behaviour), so SLOs are tightened here to force the search."""
    import dataclasses
    for seed in (1, 2, 3):
        reqs = sample_requests(5, seed=seed)
        for r in reqs:
            r.slo = dataclasses.replace(
                r.slo,
                e2e=r.slo.e2e * 0.2 if r.slo.e2e else None,
                ttft=r.slo.ttft * 0.02 if r.slo.ttft else None,
                tpot=r.slo.tpot * 0.5 if r.slo.tpot else None)
        arrays = as_arrays(reqs)
        _, _, g_opt, _ = exhaustive_search(arrays, PAPER_TABLE2, 2)
        # parallel chains (best of 3 seeds), as the jitted annealer runs
        res = [priority_mapping(arrays, PAPER_TABLE2, 2,
                                SAParams(seed=s, iters=300,
                                         budget_mode="per_level"))
               for s in (0, 1, 2)]
        assert not any(r.early_exit for r in res)
        g_sa = max(r.G for r in res)
        assert g_sa >= g_opt * 0.99


def test_sa_never_worse_than_both_starts():
    for seed in range(5):
        arrays = as_arrays(sample_requests(12, seed=seed))
        n = 12
        p0, b0 = fcfs_schedule(n, 4)
        g0 = evaluate(arrays, PAPER_TABLE2, p0, b0).G
        res = priority_mapping(arrays, PAPER_TABLE2, 4, SAParams(seed=seed))
        assert res.G >= g0 - 1e-12


def test_early_exit_when_all_slos_met():
    reqs = [Request(i, "code", 10, SLO(e2e=1e6), output_len=5)
            for i in range(6)]
    res = priority_mapping(as_arrays(reqs), PAPER_TABLE2, 2, SAParams())
    assert res.early_exit


def test_latency_model_closed_form_decode():
    m = PAPER_TABLE2
    for b in (1, 4):
        for li in (50, 700):
            for lo in (1, 13, 200):
                explicit = sum(m.per_token_decode_time(b, li + k)
                               for k in range(1, lo + 1))
                assert m.decode_time(b, li, lo) == pytest.approx(
                    explicit, rel=1e-9)


def test_fit_recovers_exact_coefficients():
    true = PAPER_TABLE2
    pre = [(b, l, true.prefill_time(b, l))
           for b in (1, 2, 4, 8) for l in (100, 400, 900, 1500)]
    dec = [(b, l, true.per_token_decode_time(b, l))
           for b in (1, 2, 4, 8) for l in (100, 400, 900, 1500)]
    m = fit(pre, dec)
    np.testing.assert_allclose(m.as_tuple(), true.as_tuple(), rtol=1e-6,
                               atol=1e-12)
