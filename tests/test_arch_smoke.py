"""Per-architecture smoke tests: REDUCED variants (2 layers, d_model<=512,
<=4 experts) run one forward pass, a short decode, and one train step on
CPU, asserting output shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, get_reduced
from repro.models import forward_decode, forward_full, init_cache, init_params
from repro.train import optimizer as opt
from repro.train.train_step import train_step

B, S = 2, 16


def _inputs(cfg, key):
    kw = {}
    if cfg.uses_extra_embeds:
        kw["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                         jnp.dtype(cfg.dtype))
        tokens = None
    elif cfg.num_codebooks:
        tokens = jax.random.randint(key, (B, S, cfg.num_codebooks), 0,
                                    cfg.vocab_size)
    else:
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return tokens, kw


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward_and_decode(arch):
    cfg = get_reduced(arch)
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    tokens, kw = _inputs(cfg, jax.random.PRNGKey(1))

    cache = init_cache(cfg, B, 64)
    logits, cache, aux = forward_full(params, cfg, tokens=tokens,
                                      cache=cache, **kw)
    if cfg.num_codebooks:
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    assert np.all(np.asarray(cache["pos"]) == S)

    # a few decode steps
    for _ in range(3):
        if cfg.uses_extra_embeds:
            step_kw = {"embeds": kw["embeds"][:, -1:]}
            toks = None
        elif cfg.num_codebooks:
            toks = tokens[:, -1:]
            step_kw = {}
        else:
            toks = tokens[:, -1:]
            step_kw = {}
        logits, cache = forward_decode(params, cfg, tokens=toks, cache=cache,
                                       **step_kw)
        assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_train_step(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    tokens, kw = _inputs(cfg, jax.random.PRNGKey(1))
    if cfg.uses_extra_embeds:
        labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                    cfg.vocab_size)
        batch = {"embeds": kw["embeds"], "labels": labels}
    elif cfg.num_codebooks:
        batch = {"tokens": tokens, "labels": tokens}
    else:
        batch = {"tokens": tokens, "labels": tokens}
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=1)
    state = opt.init(params)
    params2, state, metrics = train_step(cfg, ocfg, params, state, batch,
                                         remat=True)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # one more step decreases (or at least does not explode)
    _, _, m2 = train_step(cfg, ocfg, params2, state, batch, remat=True)
    assert np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < loss + 1.0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_shapes(arch):
    """Full configs are exercised structurally only (no allocation)."""
    cfg = get_config(arch)
    assert cfg.num_layers >= 24
    assert cfg.source
    n = cfg.param_count()
    assert n > 5e8, f"{arch}: param count {n} implausibly small"


def test_quantized_kv_decode_close_to_bf16():
    """int8 KV cache decode stays close to the exact cache (serving
    feature used by the long-context/memory §Perf iteration)."""
    import dataclasses
    from repro.models import forward_decode, forward_full, init_cache
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="q8", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                      dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 97)
    full, _, _ = forward_full(params, cfg, tokens=toks)
    cache = init_cache(cfg, 2, 32, quantized=True)
    pl, cache, _ = forward_full(params, cfg, tokens=toks[:, :8], cache=cache)
    outs = [pl[:, -1]]
    for t in range(8, 12):
        dl, cache = forward_decode(params, cfg, tokens=toks[:, t:t + 1],
                                   cache=cache)
        outs.append(dl[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - full[:, 7:])))
    scale = float(jnp.max(jnp.abs(full[:, 7:])))
    assert err < 0.05 * max(scale, 1.0), err
