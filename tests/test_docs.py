"""Docs stay runnable: every ```python block in README.md and docs/ is
executed (doctest-style smoke), and the docs pages the README promises
actually exist.  Keep doc examples small — they compile jit programs."""
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md",
             ROOT / "docs" / "ARCHITECTURE.md",
             ROOT / "docs" / "annealer.md",
             ROOT / "docs" / "paged_kv.md",
             ROOT / "docs" / "serving.md",
             ROOT / "docs" / "sharding.md",
             ROOT / "docs" / "evaluation.md"]


def _python_blocks():
    out = []
    for path in DOC_FILES:
        if not path.exists():
            continue
        text = path.read_text(encoding="utf-8")
        for k, code in enumerate(
                re.findall(r"```python\n(.*?)```", text, re.S)):
            out.append(pytest.param(code, id=f"{path.name}-{k}"))
    return out


def test_docs_exist_and_linked_from_readme():
    readme = (ROOT / "README.md").read_text(encoding="utf-8")
    for page in ("docs/ARCHITECTURE.md", "docs/annealer.md",
                 "docs/paged_kv.md", "docs/serving.md",
                 "docs/sharding.md", "docs/evaluation.md"):
        assert page in readme, f"README does not link {page}"
        assert (ROOT / page).exists(), f"{page} missing"


@pytest.mark.parametrize("code", _python_blocks())
def test_doc_code_blocks_import_and_run(code):
    exec(compile(code, "<doc-block>", "exec"), {"__name__": "__doc_block__"})
