"""Per-kernel validation: shape/dtype sweeps, interpret=True vs pure-jnp
oracle (assert_allclose)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kv,hd,bq,bk", [
    (1, 64, 4, 4, 32, 32, 32),     # MHA
    (2, 128, 8, 2, 64, 64, 32),    # GQA 4x
    (1, 256, 4, 1, 64, 64, 64),    # MQA
    (2, 128, 6, 3, 128, 128, 64),  # non-pow2 heads
])
def test_flash_attention_sweep(dtype, b, s, h, kv, hd, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 128, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 128, 2, 32), jnp.float32)
    out = flash_attention(q, k, v, window=window, block_q=32, block_k=32,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,hd,L,bk", [
    (1, 4, 4, 32, 128, 64),
    (3, 8, 2, 64, 512, 128),
    (2, 16, 8, 128, 256, 256),
])
def test_decode_attention_sweep(dtype, b, h, kv, hd, L, bk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, hd), dtype)
    kc = jax.random.normal(ks[1], (b, L, kv, hd), dtype)
    vc = jax.random.normal(ks[2], (b, L, kv, hd), dtype)
    nv = jnp.asarray(np.linspace(1, L, b).astype(np.int32))
    out = decode_attention(q, kc, vc, nv, block_k=bk, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, nv)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), **_tol(dtype))


def test_decode_attention_masks_tail_block():
    """n_valid inside the first block: later blocks fully skipped."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 32), jnp.float32)
    kc = jax.random.normal(ks[1], (1, 256, 4, 32), jnp.float32)
    vc = jax.random.normal(ks[2], (1, 256, 4, 32), jnp.float32)
    nv = jnp.array([3], jnp.int32)
    out = decode_attention(q, kc, vc, nv, block_k=64, interpret=True)
    want = ref.decode_attention_ref(q, kc, vc, nv)
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,nh,hd,ds,chunk", [
    (1, 64, 2, 32, 16, 16),
    (2, 128, 4, 64, 32, 32),
    (1, 256, 3, 32, 64, 64),
])
def test_ssd_scan_sweep(dtype, b, s, nh, hd, ds, chunk):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (b, s, nh, hd), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    B = jax.random.normal(ks[3], (b, s, ds), dtype)
    C = jax.random.normal(ks[4], (b, s, ds), dtype)
    y, st = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, str_ = ref.ssd_ref(x, dt, A, B, C, chunk=chunk)
    tol = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(y.astype(jnp.float32),
                               yr.astype(jnp.float32), **tol)
    np.testing.assert_allclose(st, str_, atol=2e-3, rtol=2e-3)


def test_model_ssd_matches_oracle():
    """The model's chunked XLA path agrees with the sequential oracle."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    b, s, nh, hd, ds = 2, 96, 3, 16, 8
    x = jax.random.normal(ks[0], (b, s, nh, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    B = jax.random.normal(ks[3], (b, s, ds))
    C = jax.random.normal(ks[4], (b, s, ds))
    y, st = ssd_chunked(x, dt, A, B, C, 32)
    yr, str_ = ref.ssd_ref(x, dt, A, B, C, chunk=32)
    np.testing.assert_allclose(y, yr, atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(st, str_, atol=2e-3, rtol=2e-3)


def test_ssd_scan_init_state_continuation():
    """Splitting a sequence across two scans with state carry == one scan."""
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    b, s, nh, hd, ds = 1, 128, 2, 16, 8
    x = jax.random.normal(ks[0], (b, s, nh, hd), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    B = jax.random.normal(ks[3], (b, s, ds))
    C = jax.random.normal(ks[4], (b, s, ds))
    y_all, st_all = ref.ssd_ref(x, dt, A, B, C, chunk=32)
    h = s // 2
    y1, st1 = ref.ssd_ref(x[:, :h], dt[:, :h], A, B[:, :h], C[:, :h], 32)
    from repro.models.ssm import ssd_chunked
    y2, st2 = ssd_chunked(x[:, h:], dt[:, h:], A, B[:, h:], C[:, h:], 32,
                          init_state=st1)
    np.testing.assert_allclose(y2, y_all[:, h:], atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(st2, st_all, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("b,h,kv,hd,L,bk", [
    (2, 8, 2, 64, 256, 64),
    (1, 4, 4, 32, 128, 128),
])
def test_decode_attention_q8(b, h, kv, hd, L, bk):
    """int8-KV flash-decode kernel vs dequantized bf16 oracle."""
    from repro.kernels.decode_attention_q8 import decode_attention_q8
    from repro.models.cache import dequantize_kv, quantize_kv
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (b, L, kv, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (b, L, kv, hd), jnp.float32)
    kq, ksc = quantize_kv(kc)
    vq, vsc = quantize_kv(vc)
    nv = jnp.asarray(np.linspace(L // 2, L, b).astype(np.int32))
    out = decode_attention_q8(q, kq, ksc, vq, vsc, nv, block_k=bk,
                              interpret=True)
    want = ref.decode_attention_ref(
        q, dequantize_kv(kq, ksc).astype(jnp.float32),
        dequantize_kv(vq, vsc).astype(jnp.float32), nv)
    np.testing.assert_allclose(out, want, atol=5e-3, rtol=5e-3)
    # and close to the unquantized attention
    exact = ref.decode_attention_ref(q, kc, vc, nv)
    assert float(jnp.max(jnp.abs(out - exact))) < 0.15


def test_model_forward_via_pallas_kernels():
    """forward_full routed through the Pallas flash-attention kernel
    (interpret mode) matches the XLA einsum path."""
    from repro.kernels import ops as kops
    from repro.models import ModelConfig, forward_full, init_params
    from repro.models.attention import set_attention_kernels
    cfg = ModelConfig(name="kd", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                      dtype="float32", sliding_window=24)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 97)
    want, _, _ = forward_full(params, cfg, tokens=toks)
    kops.set_kernel_mode("interpret")
    set_attention_kernels(True)
    try:
        got, _, _ = forward_full(params, cfg, tokens=toks)
    finally:
        set_attention_kernels(False)
        kops.set_kernel_mode("auto")
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("b,s,h,kv,hd,window,bq,bk", [
    (1, 64, 4, 4, 32, 0, 32, 32),     # MHA
    (2, 64, 4, 2, 32, 0, 32, 32),     # GQA
    (1, 64, 4, 2, 32, 24, 32, 32),    # sliding window
    (1, 128, 6, 3, 64, 0, 64, 32),    # non-pow2 heads, rectangular blocks
])
def test_flash_attention_backward(b, s, h, kv, hd, window, bq, bk):
    """custom_vjp Pallas backward (dq/dk/dv) vs jax.grad of the oracle."""
    from repro.kernels.flash_attention_bwd import flash_attention_vjp
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    ct = jax.random.normal(ks[3], (b, s, h, hd), jnp.float32)

    def f_pal(q, k, v):
        return jnp.sum(flash_attention_vjp(q, k, v, True, window, bq, bk,
                                           True) * ct)

    def f_ref(q, k, v):
        return jnp.sum(ref.flash_attention_ref(
            q, k, v, causal=True, window=window) * ct)

    o = flash_attention_vjp(q, k, v, True, window, bq, bk, True)
    np.testing.assert_allclose(o, ref.flash_attention_ref(
        q, k, v, causal=True, window=window), atol=3e-5, rtol=3e-5)
    g_pal = jax.grad(f_pal, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, r in zip(g_pal, g_ref):
        np.testing.assert_allclose(a, r, atol=3e-4, rtol=3e-4)
