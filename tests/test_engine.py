"""Serving-engine integration tests: continuous batching, planned batches,
profiler capture, SSM engine path, and greedy-decode reproducibility."""
import jax
import numpy as np
import pytest

from repro.core.profiler import LatencyProfiler
from repro.core.slo import SLO, Request
from repro.engine.engine import Engine
from repro.engine.request import RuntimeRequest
from repro.models import ModelConfig, SSMConfig, init_params

CFG = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                  dtype="float32")


def _rts(n, seed=0, vocab=128, max_new=6):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        ln = int(rng.integers(8, 40))
        out.append(RuntimeRequest(
            request=Request(req_id=i, task_type="chat", input_len=ln,
                            slo=SLO(ttft=100.0, tpot=10.0)),
            prompt_tokens=rng.integers(0, vocab, ln).astype(np.int32),
            max_new_tokens=max_new))
    return out


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def test_fcfs_completes_all(params):
    eng = Engine(CFG, params, max_slots=3, max_seq_len=128)
    out = eng.run_fcfs(_rts(7))
    assert len(out) == 7
    for v in out.values():
        assert len(v["tokens"]) == 6
        assert v["e2e"] >= v["ttft"] > 0


def test_idle_wait_pulls_arrival_despite_float_rounding(params):
    """Regression: with a carried-over engine clock t0 where
    ``(t0 + a) - t0`` rounds *below* the arrival offset ``a``, the old
    pull condition (``a <= clock - t0``) never admitted the request the
    idle-wait had just advanced the clock to, livelocking run_policy.
    The pair below is such a float pair."""
    t0, a = 6.221853067085783, 0.013274810726759588
    assert (t0 + a) - t0 < a            # the pair still triggers rounding
    eng = Engine(CFG, params, max_slots=1, max_seq_len=128)
    eng.clock = t0
    rts = _rts(1)
    rts[0].request.arrival_time = a
    out = eng.run_policy(rts, "fcfs", respect_arrivals=True)
    assert len(out[0]["tokens"]) == 6


def test_planned_batches_execute_in_order(params):
    eng = Engine(CFG, params, max_slots=4, max_seq_len=128)
    rts = _rts(6, seed=1)
    out = eng.run_planned([rts[:3], rts[3:]])
    # batch 2 requests must start strictly after batch 1 requests finished
    t_end_b1 = max(out[r.req_id]["e2e"] for r in rts[:3])
    t_start_b2 = min(out[r.req_id]["ttft"] for r in rts[3:])
    assert t_start_b2 >= t_end_b1 * 0.5    # ttft includes waiting


def test_profiler_collects_samples(params):
    prof = LatencyProfiler()
    eng = Engine(CFG, params, max_slots=2, max_seq_len=128, profiler=prof)
    eng.run_fcfs(_rts(4, seed=2))
    assert len(prof.prefill_samples) == 4
    assert len(prof.decode_samples) > 0
    m = prof.fit()
    assert m.prefill_time(1, 100) > 0


def test_greedy_decode_reproducible(params):
    outs = []
    for _ in range(2):
        eng = Engine(CFG, params, max_slots=2, max_seq_len=128, seed=7)
        res = eng.run_fcfs(_rts(3, seed=3))
        outs.append({k: tuple(v["tokens"]) for k, v in res.items()})
    assert outs[0] == outs[1]


def test_engine_ssm_arch():
    cfg = ModelConfig(name="tiny-ssm", family="ssm", num_layers=2,
                      d_model=64, num_heads=0, num_kv_heads=0, d_ff=0,
                      vocab_size=128, dtype="float32",
                      ssm=SSMConfig(d_state=16, head_dim=32, chunk_size=16))
    params = init_params(jax.random.PRNGKey(1), cfg)
    eng = Engine(cfg, params, max_slots=2, max_seq_len=128)
    out = eng.run_fcfs(_rts(3, seed=4, max_new=4))
    assert all(len(v["tokens"]) == 4 for v in out.values())


def test_engine_matches_raw_forward(params):
    """Engine FCFS greedy tokens == direct prefill+decode greedy tokens."""
    import jax.numpy as jnp
    from repro.models import forward_decode, forward_full, init_cache
    rt = _rts(1, seed=5)[0]
    eng = Engine(CFG, params, max_slots=1, max_seq_len=128)
    out = eng.run_fcfs([rt])[rt.req_id]

    toks = jnp.asarray(rt.prompt_tokens)[None]
    cache = init_cache(CFG, 1, 128)
    logits, cache, _ = forward_full(params, CFG, tokens=toks, cache=cache)
    want = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(5):
        lg, cache = forward_decode(params, CFG,
                                   tokens=jnp.array([[want[-1]]]),
                                   cache=cache)
        want.append(int(jnp.argmax(lg[0, 0])))
    assert out["tokens"] == want


def test_chunked_prefill_identical_generations(params):
    """Sarathi-style chunked prefill generates the same tokens as whole
    prefill (decode rounds interleave between chunks)."""
    a = Engine(CFG, params, max_slots=3, max_seq_len=128).run_fcfs(
        _rts(5, seed=6))
    b = Engine(CFG, params, max_slots=3, max_seq_len=128,
               chunked_prefill=16).run_fcfs(_rts(5, seed=6))
    assert all(a[i]["tokens"] == b[i]["tokens"] for i in a)


def test_failing_policy_leaves_engine_config_untouched(params):
    """Regression: run_policy used to execute a chunked discipline by
    mutating ``engine.chunked_prefill`` per round (with a save/restore
    dance).  The step-planner core threads the discipline through the
    per-tick plan instead — a policy that blows up mid-run must leave
    the engine's configuration exactly as constructed."""
    from repro.core.policies import Decision, SchedulingPolicy

    class Boom(SchedulingPolicy):
        def __init__(self):
            self.calls = 0

        def decide(self, view):
            self.calls += 1
            if self.calls > 1:
                raise RuntimeError("boom")
            return Decision(admit=(0,))

    eng = Engine(CFG, params, max_slots=2, max_seq_len=128)
    with pytest.raises(RuntimeError, match="boom"):
        eng.run_policy(_rts(3, seed=8), Boom(), discipline="chunked:16")
    assert eng.chunked_prefill == 0          # as constructed
    # and the mirror image: a chunk-configured engine driven under an
    # explicit stall discipline keeps its own default
    eng2 = Engine(CFG, params, max_slots=2, max_seq_len=128,
                  chunked_prefill=16)
    with pytest.raises(RuntimeError, match="boom"):
        eng2.run_policy(_rts(3, seed=8), Boom(), discipline="stall")
    assert eng2.chunked_prefill == 16


def test_chunked_prefill_exact_ring_and_ssm():
    """forward_chunk == forward_full for windowed (ring) and SSM caches."""
    import jax.numpy as jnp
    from repro.models import (ModelConfig, SSMConfig, forward_decode,
                              forward_full, init_cache, init_params)
    from repro.models.model import forward_chunk
    for cfg in (
        ModelConfig(name="s", family="dense", num_layers=2, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                    dtype="float32", sliding_window=10),
        ModelConfig(name="m", family="ssm", num_layers=2, d_model=64,
                    num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=97,
                    dtype="float32",
                    ssm=SSMConfig(d_state=16, head_dim=32, chunk_size=8)),
    ):
        p = init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 97)
        ca = init_cache(cfg, 2, 64)
        la, ca, _ = forward_full(p, cfg, tokens=toks, cache=ca)
        cb = init_cache(cfg, 2, 64)
        for i in range(0, 24, 8):
            lb, cb = forward_chunk(p, cfg, tokens=toks[:, i:i + 8], cache=cb)
        assert float(jnp.max(jnp.abs(lb[:, 0] - la[:, -1]))) < 1e-3
        da, _ = forward_decode(p, cfg, tokens=toks[:, -1:], cache=ca)
        db, _ = forward_decode(p, cfg, tokens=toks[:, -1:], cache=cb)
        assert float(jnp.max(jnp.abs(da - db))) < 1e-3
