"""Trace-replay workload layer: checked-in profile integrity, histogram
round-trips, arrival-process calibration, the shared
``(Request, prompt_tokens)`` convention, and the seeded-determinism
regression that guards ``benchmarks/bench_goodput.py``'s artifact
contract (same seed + trace ⇒ byte-identical results across runs)."""
import json
import pathlib
import sys

import numpy as np
import pytest

from repro.core import PAPER_TABLE2, simulate
from repro.core.policies import make
from repro.data.traces import (ARRIVAL_PROCESSES, BUILTIN_TRACES,
                               TRACES_DIR, LengthHistogram, TraceProfile,
                               load_trace_profile, make_arrivals,
                               sample_trace, sample_trace_workload)

ROOT = pathlib.Path(__file__).resolve().parents[1]


# ------------------------------------------------------ checked-in traces
@pytest.mark.parametrize("name", BUILTIN_TRACES)
def test_builtin_profile_loads_and_is_tagged(name):
    prof = load_trace_profile(name)
    assert prof.name == name
    assert prof.task_type in ("code", "chat")
    # every paper dataset carries at least one SLO dimension + its source
    assert any(v is not None
               for v in (prof.slo.ttft, prof.slo.tpot, prof.slo.e2e))
    assert prof.source.startswith("hf:")
    # the JSON on disk round-trips exactly through to_json/from_json
    with open(TRACES_DIR / f"{name}.json") as f:
        raw = json.load(f)
    assert TraceProfile.from_json(prof.to_json()) == prof
    assert TraceProfile.from_json(raw) == prof


def test_unknown_profile_is_a_clear_error():
    with pytest.raises(FileNotFoundError, match="built-ins"):
        load_trace_profile("no-such-trace")


# -------------------------------------------------------------- histogram
def test_histogram_sampling_stays_in_support():
    rng = np.random.default_rng(0)
    h = LengthHistogram.from_samples(rng.lognormal(5.0, 0.8, 5000))
    vals = h.sample(np.random.default_rng(1), 2000)
    assert vals.min() >= 1
    assert h.edges[0] - 1 <= vals.min() <= vals.max() <= h.edges[-1]
    # the distilled histogram reproduces the source's median to ~25 %
    assert 0.75 < np.median(vals) / np.exp(5.0) < 1.25


def test_histogram_validation():
    with pytest.raises(ValueError):
        LengthHistogram(edges=(1.0, 2.0), counts=(1.0, 1.0))
    with pytest.raises(ValueError):
        LengthHistogram(edges=(2.0, 1.0, 3.0), counts=(1.0, 1.0))
    with pytest.raises(ValueError):
        LengthHistogram(edges=(1.0, 2.0, 3.0), counts=(0.0, 0.0))


# --------------------------------------------------------------- arrivals
@pytest.mark.parametrize("process", sorted(ARRIVAL_PROCESSES))
def test_arrivals_calibrated_to_mean_rate(process):
    """All three processes are mean-rate calibrated, so attainment
    curves are load-comparable across them."""
    kw = {"period": 10.0} if process == "diurnal" else {}
    t = make_arrivals(4000, 50.0, process, seed=7, **kw)
    assert (np.diff(t) >= 0).all()
    assert t.min() >= 0
    rate = len(t) / t[-1]
    assert 0.85 * 50.0 < rate < 1.15 * 50.0
    assert np.array_equal(t, make_arrivals(4000, 50.0, process,
                                           seed=7, **kw))


def test_bursty_is_burstier_than_poisson():
    gp = np.diff(make_arrivals(4000, 50.0, "poisson", seed=3))
    gb = np.diff(make_arrivals(4000, 50.0, "bursty", seed=3))
    cv = lambda g: np.std(g) / np.mean(g)           # noqa: E731
    assert cv(gb) > cv(gp)


def test_zero_rate_means_everyone_at_t0():
    assert make_arrivals(16, 0.0, "poisson").max() == 0.0


# ------------------------------------------------------- trace generators
def test_sample_trace_is_seed_deterministic():
    a = sample_trace(64, rate=20.0, seed=11)
    b = sample_trace(64, rate=20.0, seed=11)
    for ra, rb in zip(a, b):
        assert (ra.req_id, ra.task_type, ra.input_len, ra.output_len,
                ra.arrival_time, ra.slo) == \
               (rb.req_id, rb.task_type, rb.input_len, rb.output_len,
                rb.arrival_time, rb.slo)
    c = sample_trace(64, rate=20.0, seed=12)
    assert any(ra.input_len != rc.input_len for ra, rc in zip(a, c))


def test_workload_twin_shares_the_request_stream():
    """sample_trace_workload replays the exact request stream of
    sample_trace at the same seed; tokens are a separate stream."""
    reqs = sample_trace(32, rate=5.0, seed=4, max_input=48)
    pairs = sample_trace_workload(32, 128, rate=5.0, seed=4, max_input=48)
    for r, (rw, toks) in zip(reqs, pairs):
        assert (r.req_id, r.input_len, r.output_len, r.arrival_time) == \
               (rw.req_id, rw.input_len, rw.output_len, rw.arrival_time)
        assert len(toks) == r.input_len
        assert toks.dtype == np.int32 and 0 <= toks.min() \
            and toks.max() < 128


def test_length_clipping_and_slo_scaling():
    reqs = sample_trace(64, seed=2, max_input=48, max_output=16,
                        slo_scale=0.5)
    assert max(r.input_len for r in reqs) <= 48
    assert max(r.output_len for r in reqs) <= 16
    base = {p: load_trace_profile(p).slo for p in BUILTIN_TRACES}
    for r in reqs:
        ref = next(s for s in base.values()
                   if (s.e2e is None) == (r.slo.e2e is None))
        for k in ("ttft", "tpot", "e2e"):
            b, got = getattr(ref, k), getattr(r.slo, k)
            assert (b is None) == (got is None)
            if b is not None:
                assert got == pytest.approx(b * 0.5)


def test_bad_mix_rejected():
    with pytest.raises(ValueError):
        sample_trace(8, mix=[1.0])          # one weight, two profiles
    with pytest.raises(ValueError):
        sample_trace(8, mix=[0.0, 0.0])


# --------------------------------------------- seeded-determinism (bench)
def _sim_once():
    reqs = sample_trace(200, rate=30.0, seed=9)
    for r in reqs:
        r.predicted_output_len = r.output_len
    pol = make("index", model=PAPER_TABLE2)
    return simulate(reqs, PAPER_TABLE2, 8, pol, respect_arrivals=True)


def test_simresult_is_byte_identical_across_runs():
    """Same seed + trace ⇒ byte-identical SimResult: repr equality is
    deliberate — any float drifting by 1 ulp fails."""
    a, b = _sim_once(), _sim_once()
    assert repr(a) == repr(b)
    assert a.e2e == b.e2e and a.ttft == b.ttft and a.met == b.met


def test_bench_goodput_rows_are_byte_identical_across_runs():
    """The artifact contract of benchmarks/bench_goodput.py: everything
    except the wall-clock us_per_call column is a pure function of the
    seed (BENCH_goodput.json and the attainment CSV diff clean)."""
    sys.path.insert(0, str(ROOT))
    try:
        from benchmarks.bench_goodput import sweep
    finally:
        sys.path.pop(0)
    out = []
    for _ in range(2):
        rows, payload, curve = sweep(
            configs=("qwen2.5-7b",), policies=("fcfs", "index"),
            loads=(0.8,), n=120)
        out.append((json.dumps(payload, sort_keys=True), curve,
                    [[r[0], r[2]] for r in rows]))   # drop us_per_call
    assert out[0] == out[1]
