"""Differential simulator-vs-engine conformance: every registry policy
× discipline runs the *same seeded trace-shaped workload* through the
event core (``events.simulate``) and the real engine
(``Engine.run_policy``), and the two executions must tell the same
story.  This is the trust anchor for ``benchmarks/bench_goodput.py``:
its attainment curves come from the simulator at scales the CI engine
cannot reach, and this suite is what pins the simulator to the metal.

The engine runs on a wall clock (CPU jit timings), the simulator on the
latency model fit from that same engine's profiler — so the contract is
*decision and accounting parity*, not clock equality.  Documented
tolerances:

  * completion set, per-request token counts: **exact**
  * SLO met flags at both SLO extremes (budgets ~1e6× vs ~1e-9× the
    runtime): **exact** — extreme margins make the flags robust to any
    plausible clock divergence
  * preemption counts on the non-contended workload: **exact** (zero);
    on the contended mix both executors must take the eviction path
    (counts > 0), but counts are not compared — eviction triggers sit
    on wall-clock thresholds
  * finish order: per-request rank displacement ≤ 2 (the workload gives
    every request a distinct output length, so no two requests finish
    in the same decode round — but two *pending* requests with
    near-tied priority indices may swap admission slots when the wall
    clock and the modelled clock disagree by a hair, which displaces
    the finish ranks of that adjacent pair)
  * per-request e2e: within **6×** of the modelled value, and the run's
    total latency within **3×** — CPU jit timings are noisy, but the
    fitted model must stay on the engine's actual scale
"""
import math

import numpy as np
import pytest

from repro.core import SAParams, simulate
from repro.core.policies import make, make_discipline
from repro.core.profiler import LatencyProfiler
from repro.core.slo import SLO, Request
from repro.data.traces import sample_trace_workload

#: every policy that can appear in a bench_goodput row
POLICIES = ["fcfs", "slo-reanneal", "slo-preempt",
            "index", "index:sjf", "index:edf", "dynamic-chunk"]
DISCIPLINES = ["stall", "chunked:16"]

N = 8
MAX_SLOTS = 2
VOCAB = 128
E2E_TOL = 6.0       # per-request engine/sim e2e ratio bound
SUM_TOL = 3.0       # whole-run total-latency ratio bound


def _workload(seed: int = 42, slo_scale: float = 1e6):
    """Trace-shaped offline pool: lengths/SLO kinds replayed from the
    checked-in histograms, outputs reassigned to distinct values so no
    two requests can finish in the same decode round (finish order is
    then exact in both executors)."""
    pairs = sample_trace_workload(N, VOCAB, seed=seed, rate=0.0,
                                  max_input=48, slo_scale=slo_scale)
    for i, (r, _) in enumerate(pairs):
        r.output_len = 3 + (i * 3) % 16
        r.predicted_output_len = r.output_len
    return pairs


def _policy(key, model):
    # blanket context: factories ignore what they don't need.  The
    # dynamic-chunk bounds keep its adaptive chunk inside the engine's
    # warmed jit sizes.
    return make(key, model=model, max_batch=MAX_SLOTS,
                sa_params=SAParams(seed=0), min_chunk=8, max_chunk=16)


@pytest.fixture(scope="module")
def rig():
    jax = pytest.importorskip("jax")
    from repro.engine.engine import Engine
    from repro.models import ModelConfig, init_params

    cfg = ModelConfig(name="conf-tiny", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=VOCAB, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prof = LatencyProfiler()
    # one shared engine: jit warm-ups amortize across the whole matrix,
    # and the prefix cache is off so sim and engine price identical
    # prefill lengths
    eng = Engine(cfg, params, max_slots=MAX_SLOTS, max_seq_len=128,
                 profiler=prof, prefix_cache=False, temperature=0.0)
    eng.run_fcfs(_rts(_workload(seed=0)))     # profiling warm-up pass
    # nonneg: unconstrained OLS on the warm-up's noisy wall-clock
    # samples can extrapolate to *negative* step costs, which would run
    # the simulator clock backwards and scramble finish order
    model = prof.fit(nonneg=True)
    return eng, model


def _rts(pairs):
    from repro.engine.request import RuntimeRequest
    return [RuntimeRequest(request=r, prompt_tokens=p,
                           max_new_tokens=r.output_len)
            for r, p in pairs]


def _finish_order(per_req_e2e):
    """req_ids by completion instant — submit/arrival is one shared
    origin in both executors, so e2e order *is* finish order."""
    return [rid for rid, _ in sorted(per_req_e2e.items(),
                                     key=lambda kv: (kv[1], kv[0]))]


@pytest.mark.parametrize("disc_key", DISCIPLINES)
@pytest.mark.parametrize("policy_key", POLICIES)
def test_policy_conformance(rig, policy_key, disc_key):
    eng, model = rig

    # --- simulator leg (fresh requests + fresh policy object)
    sim_pairs = _workload()
    sim_res = simulate([r for r, _ in sim_pairs], model, MAX_SLOTS,
                       _policy(policy_key, model),
                       discipline=make_discipline(disc_key),
                       respect_arrivals=False)

    # --- engine leg (identical seeded workload, its own objects)
    eng_pairs = _workload()
    out = eng.run_policy(_rts(eng_pairs), _policy(policy_key, model),
                         discipline=make_discipline(disc_key),
                         model=model)

    # completion set + token counts: exact
    assert set(out) == set(sim_res.e2e) == {r.req_id
                                            for r, _ in eng_pairs}
    for r, _ in eng_pairs:
        assert len(out[r.req_id]["tokens"]) == r.output_len

    # preemption counts: exact (loose budgets -> none anywhere)
    eng_pre = sum(v["preemptions"] for v in out.values())
    assert sim_res.n_preempted == eng_pre == 0

    # met flags: exact under the huge-margin SLOs
    assert all(sim_res.met.values())
    assert all(v["met"] for v in out.values())

    # finish order: rank displacement <= 2 (near-tied priority indices
    # may swap an adjacent admission pair across the two clocks)
    eng_order = _finish_order({k: v["e2e"] for k, v in out.items()})
    sim_order = _finish_order(sim_res.e2e)
    sim_rank = {rid: k for k, rid in enumerate(sim_order)}
    for k, rid in enumerate(eng_order):
        assert abs(k - sim_rank[rid]) <= 2, \
            f"req {rid} finished #{k} on the engine but " \
            f"#{sim_rank[rid]} in the sim ({policy_key}/{disc_key}): " \
            f"{eng_order} vs {sim_order}"

    # per-request e2e within the documented ratio tolerance
    for rid, sim_e2e in sim_res.e2e.items():
        ratio = out[rid]["e2e"] / sim_e2e
        assert 1.0 / E2E_TOL < ratio < E2E_TOL, \
            f"req {rid}: engine e2e {out[rid]['e2e']:.4f}s vs sim " \
            f"{sim_e2e:.4f}s ({policy_key}/{disc_key})"
    total_ratio = sum(v["e2e"] for v in out.values()) \
        / sim_res.total_latency
    assert 1.0 / SUM_TOL < total_ratio < SUM_TOL


#: streaming rows: the chunked disciplines the serving loop used to
#: refuse.  ``None`` = no explicit discipline — the executor must adopt
#: the policy's own (dynamic-chunk carries AdaptiveChunkedPrefill).
STREAM_ROWS = [("fcfs", "chunked:16"), ("dynamic-chunk", None)]


@pytest.mark.parametrize("policy_key,disc_key", STREAM_ROWS)
def test_streaming_conformance(rig, policy_key, disc_key):
    """Third executor: the live ServeLoop.  Wall-clock streaming changes
    *when* work happens (arrival release, overlapped dispatch, chunk
    spans riding serving ticks), never *what* is computed — the
    streamed greedy tokens must equal the sync engine's exactly, and
    all three executors must agree on the completion set and the
    extreme-margin met flags.  (The simulator carries token *counts*,
    not contents, so content parity is engine-vs-loop only.)"""
    from repro.serving import ServeLoop
    eng, model = rig

    def _disc():
        return make_discipline(disc_key) if disc_key else None

    # --- sync engine leg
    out = eng.run_policy(_rts(_workload()), _policy(policy_key, model),
                         discipline=_disc(), model=model)

    # --- simulator leg
    sim_res = simulate([r for r, _ in _workload()], model, MAX_SLOTS,
                       _policy(policy_key, model), discipline=_disc(),
                       respect_arrivals=False)

    # --- streaming leg: identical seeded trace served live
    srv_pairs = _workload()
    loop = ServeLoop(eng, _policy(policy_key, model), model=model,
                     discipline=_disc())
    assert loop.disc.chunk_size, "row must exercise a chunked plan"
    loop.start(warm_lengths=[len(p) for _, p in srv_pairs])
    loop.submit_trace(srv_pairs)
    srv = loop.serve()

    # completion sets: all three executors serve exactly the workload
    ids = {r.req_id for r, _ in srv_pairs}
    assert set(srv) == set(out) == set(sim_res.e2e) == ids

    # streamed tokens == sync engine tokens, budgets exactly honoured
    for r, _ in srv_pairs:
        assert srv[r.req_id]["tokens"] == out[r.req_id]["tokens"]
        assert len(srv[r.req_id]["tokens"]) == r.output_len

    # met flags at the huge-margin extreme: met everywhere, on both the
    # engine clock and the measured wall clock
    assert all(sim_res.met.values())
    assert all(v["met"] for v in out.values())
    assert all(v["met"] for v in srv.values())
    assert all(v["met_wall"] for v in srv.values())

    # the loop really executed prefill through the tick plan (prefix
    # cache is off, so plan spans cover every prompt token at least once)
    total_prompt = sum(len(p) for _, p in srv_pairs)
    assert sum(g.prefill_tokens for g in loop.metrics.gauges) \
        >= total_prompt


def test_streaming_met_flags_at_tiny_budgets(rig):
    """Streaming leg of the opposite SLO extreme: ~1e-9× budgets are
    unmeetable on any wall clock, and the loop must say so on both its
    accounting and measured flags — matching the sync executors."""
    from repro.serving import ServeLoop
    eng, model = rig
    pairs = _workload(slo_scale=1e-9)
    loop = ServeLoop(eng, _policy("fcfs", model), model=model,
                     discipline=make_discipline("chunked:16"))
    loop.start(warm_lengths=[len(p) for _, p in pairs])
    loop.submit_trace(pairs)
    srv = loop.serve()
    assert len(srv) == N
    assert not any(v["met"] for v in srv.values())
    assert not any(v["met_wall"] for v in srv.values())


def test_met_flags_agree_at_tiny_budgets(rig):
    """The opposite SLO extreme: budgets ~1e-9× below any achievable
    latency — both executors must report zero attainment."""
    eng, model = rig
    sim_pairs = _workload(slo_scale=1e-9)
    sim_res = simulate([r for r, _ in sim_pairs], model, MAX_SLOTS,
                       _policy("fcfs", model), respect_arrivals=False)
    out = eng.run_policy(_rts(_workload(slo_scale=1e-9)),
                         _policy("fcfs", model), model=model)
    assert not any(sim_res.met.values())
    assert not any(v["met"] for v in out.values())


def _contended(seed: int = 3):
    """Tight-TTFT interactive requests *arriving* while long
    loose-deadline jobs already hold every slot — the regime where
    slo-preempt must evict, not just reorder admission (cf.
    bench_online's engine rows).  In an offline everyone-pending-at-t=0
    pool the policy would simply admit the tight requests first, so
    arrivals are staggered and both executors run with
    ``respect_arrivals=True``."""
    rng = np.random.default_rng(seed)
    pairs, t = [], 0.0
    for i in range(9):
        if i % 3 == 2:                      # tight interactive arrival
            r = Request(i, "chat", int(rng.integers(8, 24)),
                        SLO(ttft=0.005, tpot=0.05),
                        output_len=int(rng.integers(3, 6)))
        else:                               # long job, loose deadline:
            # occupies a slot for dozens of decode rounds, so a tight
            # arrival stuck behind it blows its first-token budget at
            # any plausible clock speed unless a long job is evicted
            r = Request(i, "code", int(rng.integers(24, 56)),
                        SLO(e2e=30.0),
                        output_len=int(rng.integers(40, 60)))
        t += float(rng.exponential(0.005))
        r.arrival_time = t
        r.predicted_output_len = r.output_len
        pairs.append((r, rng.integers(0, VOCAB,
                                      r.input_len).astype(np.int32)))
    return pairs


def test_preemption_path_parity(rig):
    """Both executors must take the eviction path on the contended mix
    (counts themselves sit on wall-clock thresholds, so only the
    path — preemptions > 0 — is asserted)."""
    eng, model = rig
    sim_res = simulate([r for r, _ in _contended()], model, MAX_SLOTS,
                       _policy("slo-preempt", model),
                       respect_arrivals=True)
    out = eng.run_policy(_rts(_contended()),
                         _policy("slo-preempt", model), model=model,
                         respect_arrivals=True)
    assert sim_res.n_preempted > 0
    assert sum(v["preemptions"] for v in out.values()) > 0
    # evicted requests are re-prefilled, never dropped
    assert set(out) == set(sim_res.e2e)
    for rid, v in out.items():
        assert len(v["tokens"]) > 0
