"""Checkpoint round-trip: params + optimizer state survive save/restore and
training resumes bit-identically."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, init_params
from repro.train import optimizer as opt
from repro.train.checkpoint import restore, save
from repro.train.train_step import train_step

CFG = ModelConfig(name="ck", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                  dtype="float32")


def test_roundtrip_and_resume(tmp_path):
    params = init_params(jax.random.PRNGKey(0), CFG)
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=1)
    state = opt.init(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 128)
    batch = {"tokens": toks, "labels": toks}
    for _ in range(3):
        params, state, _ = train_step(CFG, ocfg, params, state, batch)

    path = os.path.join(tmp_path, "ckpt.npz")
    save(path, params, state, step=3, meta={"arch": CFG.name})

    template = init_params(jax.random.PRNGKey(42), CFG)   # different values
    p2, s2, step = restore(path, template, opt.init(template))
    assert step == 3
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, p2)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state.mu, s2.mu)

    # resuming from the restored state matches continuing the original
    pa, sa, ma = train_step(CFG, ocfg, params, state, batch)
    pb, sb, mb = train_step(CFG, ocfg, p2, s2, batch)
    assert float(ma["loss"]) == float(mb["loss"])
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), pa, pb)


def test_params_only_checkpoint(tmp_path):
    params = init_params(jax.random.PRNGKey(0), CFG)
    path = os.path.join(tmp_path, "p.npz")
    save(path, params)
    template = init_params(jax.random.PRNGKey(9), CFG)
    p2, s2, step = restore(path, template)
    assert s2 is None and step == 0
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, p2)
