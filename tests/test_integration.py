"""System integration: scheduler + simulator end-to-end, JAX annealer
consistency, scheduler component interplay (Algorithm 2)."""
import numpy as np
import pytest

from repro.core import (PAPER_TABLE2, SAParams, SLOAwareScheduler, as_arrays,
                        evaluate, run_fcfs_continuous, run_multi_instance,
                        run_priority_continuous)
from repro.core.profiler import (MemoryModel, OutputLengthPredictor)
from repro.data.synthetic import sample_requests


def test_scheduler_end_to_end_single_instance():
    reqs = sample_requests(16, seed=3)
    for r in reqs:
        r.predicted_output_len = r.output_len
    sched = SLOAwareScheduler(PAPER_TABLE2, num_instances=1, max_batch=4,
                              sa_params=SAParams(seed=0))
    out = sched.schedule(reqs)
    assert len(out.queues) == 1
    ids = sorted(r.req_id for b in out.queues[0].batches for r in b)
    assert ids == list(range(16))
    for b in out.queues[0].batches:
        assert 1 <= len(b) <= 4
    sim = run_priority_continuous(out.queues[0].batches, PAPER_TABLE2, 4)
    assert sim.n == 16


def test_scheduler_contended_beats_fcfs():
    """Under contention the SLO-aware order should not lose to FCFS
    (averaged over seeds)."""
    gains = []
    for seed in (11, 12, 13, 14, 15):
        reqs = sample_requests(20, seed=seed)
        for r in reqs:
            r.predicted_output_len = r.output_len   # oracle predictor
        fcfs = run_fcfs_continuous(reqs, PAPER_TABLE2, 2)
        sched = SLOAwareScheduler(PAPER_TABLE2, num_instances=1, max_batch=2,
                                  sa_params=SAParams(
                                      seed=0, budget_mode="per_level"))
        out = sched.schedule(reqs)
        slo = run_priority_continuous(out.queues[0].batches, PAPER_TABLE2, 2)
        gains.append(slo.G / fcfs.G if fcfs.G > 0 else 1.0)
    assert np.mean(gains) > 1.0, gains


def test_multi_instance_assignment_balances():
    reqs = sample_requests(30, seed=7)
    for r in reqs:
        r.predicted_output_len = r.output_len
    mem = MemoryModel(total_memory=32e9, mu=0.9, sigma_per_token=2e5)
    sched = SLOAwareScheduler(PAPER_TABLE2, num_instances=3, max_batch=4,
                              memory=mem, sa_params=SAParams(seed=0))
    out = sched.schedule(reqs)
    sizes = [len(q) for q in out.queues]
    assert sum(sizes) == 30
    assert max(sizes) - min(sizes) <= 12   # roughly balanced
    assert set(out.assignment.values()) <= {0, 1, 2}


def test_memory_model_eq20():
    mem = MemoryModel(total_memory=10e9, mu=0.8, sigma_per_token=1e5)
    assert mem.token_capacity(10e9) == int(10e9 * 0.8 / 1e5)
    # observe runs and refit
    mem.observe_run(peak_mem=8e9, avail_mem=10e9, tokens=50_000,
                    mem_used=6e9)
    assert mem.mu == pytest.approx(0.8)
    assert mem.sigma == pytest.approx(6e9 / 50_000)


def test_output_length_predictor_converges():
    pred = OutputLengthPredictor(seed=0)
    rng = np.random.default_rng(0)
    for _ in range(500):
        pred.observe("code", int(rng.normal(300, 30)))
    mean = np.mean([pred.predict("code") for _ in range(200)])
    assert abs(mean - 300) < 30
    assert pred.predict_mean("code") == pytest.approx(300, abs=10)


def test_jax_annealer_agrees_with_numpy_objective():
    from repro.core.annealing_jax import JaxSAConfig, priority_mapping_jax
    reqs = sample_requests(12, seed=2)
    arrays = as_arrays(reqs)
    perm, bid, g = priority_mapping_jax(arrays, PAPER_TABLE2, 3,
                                        JaxSAConfig(iters=50, num_chains=2),
                                        seed=0)
    ev = evaluate(arrays, PAPER_TABLE2, perm, bid)
    assert abs(ev.G - g) / max(g, 1e-12) < 2e-3   # f32 vs f64 tolerance
    assert sorted(perm.tolist()) == list(range(12))
    assert np.bincount(bid).max() <= 3


def test_simulator_planned_vs_continuous_semantics():
    """Planned lock-step must never finish earlier than continuous with the
    same order/batching (continuous dominates)."""
    reqs = sample_requests(12, seed=9)
    for r in reqs:
        r.predicted_output_len = r.output_len
    batches = [reqs[i:i + 3] for i in range(0, 12, 3)]
    from repro.core.simulator import run_planned
    locked = run_planned(batches, PAPER_TABLE2)
    cont = run_priority_continuous(batches, PAPER_TABLE2, 3)
    assert cont.total_latency <= locked.total_latency * 1.05


def test_online_scheduling_under_load():
    """Online re-annealing never loses to FCFS under heavy arrivals."""
    import numpy as np
    from repro.core import SAParams
    from repro.core.online import simulate_online
    rng = np.random.default_rng(3)
    reqs = sample_requests(24, seed=8)
    t = 0.0
    for r in reqs:
        t += rng.exponential(0.25)
        r.arrival_time = t
        r.predicted_output_len = r.output_len
    f = simulate_online(reqs, PAPER_TABLE2, 4, "fcfs")
    s = simulate_online(reqs, PAPER_TABLE2, 4, "slo", SAParams(seed=0))
    assert s.n == f.n == 24
    assert s.G >= f.G * 0.95


def test_metrics_report():
    from repro.core.metrics import report
    reqs = sample_requests(20, seed=4)
    sim = run_fcfs_continuous(reqs, PAPER_TABLE2, 4)
    rep = report(sim, reqs)
    assert rep.count == 20
    assert 0 <= rep.attainment <= 1
    assert rep.e2e_p50 <= rep.e2e_p90 <= rep.e2e_p99
    assert set(rep.per_task) == {"code", "chat"}
    rows = rep.rows()
    assert len(rows) == 3 and rows[0][0] == "serving_summary"
