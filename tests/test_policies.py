"""Scheduling API v2: SchedulingPolicy/ExecutionDiscipline contract,
SLO-aware preemption (core + engine), chunked-prefill semantics in the
event core with engine parity, the policy/discipline registry, the
AdmissionPolicy deprecation shim, PlannedPolicy reuse, and the
submit-time clock-mismatch regression."""
import math
import warnings

import numpy as np
import pytest

from repro.core import (PAPER_TABLE2, AdmissionPolicy, ChunkedPrefill,
                        Decision, FCFSPolicy, PlannedPolicy, SAParams,
                        SchedulingPolicy, SLOPreemptPolicy,
                        SLOReannealPolicy, StallingPrefill,
                        as_scheduling_policy, make, make_discipline,
                        simulate)
from repro.core.latency_model import LinearLatencyModel
from repro.core.policies import (SchedulerView, make_active_view,
                                 submit_base, with_remaining_slo)
from repro.core.slo import SLO, Request

# prefill = 0.5 s, per-token decode = 0.25 s (b- and length-independent)
CONST = LinearLatencyModel(0, 0, 0, 0.5, 0, 0, 0, 0.25)
# prefill = 0.01 s/token (chunk-size sensitive), decode = 0.25 s/token
PROP = LinearLatencyModel(0, 0, 0.01, 0, 0, 0, 0, 0.25)


def _req(i, li, lo, slo=None, arrival=0.0):
    return Request(i, "chat", li, slo or SLO(ttft=1e6, tpot=1e6),
                   output_len=lo, arrival_time=arrival)


# ----------------------------------------------------------- preemption
def test_preempt_core_tight_arrival_meets_slo():
    """Acceptance: a tight-SLO late arrival meets its SLO under
    SLOPreemptPolicy and misses it under plain FCFS; the evicted
    request's KV-recompute cost is charged (its e2e grows)."""
    slow = _req(0, 10, 100, SLO(e2e=1e6))          # huge slack
    tight = _req(1, 8, 3, SLO(ttft=1.0), arrival=1.0)
    fcfs = simulate([slow, tight], CONST, 1, "fcfs")
    pre = simulate([slow, tight], CONST, 1,
                   make("slo-preempt", model=CONST))
    assert not fcfs.met[1]                          # waits behind slow
    assert pre.met[1]
    assert pre.ttft[1] == pytest.approx(0.5)        # prefill right away
    # the preemption is observable, and both requests still complete
    assert pre.preemptions == {0: 1} and fcfs.n_preempted == 0
    assert pre.n == fcfs.n == 2
    assert pre.met[0]                               # victim still fine
    # honesty: victim pays the re-prefill (prompt + generated recompute)
    assert pre.e2e[0] > fcfs.e2e[0]


def test_preempt_never_evicts_negative_slack():
    """A victim whose slack cannot absorb the recompute is left alone."""
    slow = _req(0, 10, 100, SLO(e2e=25.5))          # barely feasible
    tight = _req(1, 8, 3, SLO(ttft=1.0), arrival=1.0)
    pre = simulate([slow, tight], CONST, 1,
                   make("slo-preempt", model=CONST))
    assert pre.n_preempted == 0
    assert pre.met[0]                               # slow still meets


def test_preempted_request_token_accounting():
    """The victim keeps its generated tokens and TTFT; re-admission
    re-prefills l_i + generated and emits the next token."""
    slow = _req(0, 10, 100, SLO(e2e=1e6))
    tight = _req(1, 8, 3, SLO(ttft=1.0), arrival=1.0)
    pre = simulate([slow, tight], CONST, 1,
                   make("slo-preempt", model=CONST))
    fcfs = simulate([slow, tight], CONST, 1, "fcfs")
    # TTFT survives the preemption: first token at the original prefill
    assert pre.ttft[0] == pytest.approx(fcfs.ttft[0]) == pytest.approx(0.5)
    # e2e grows by exactly: idle tail of tight's service + re-prefill −
    # the decode round that would have run instead (CONST timings)
    assert pre.e2e[0] > fcfs.e2e[0]
    assert pre.tpot[0] == pytest.approx((pre.e2e[0] - pre.ttft[0]) / 100)


def test_preempt_e2e_tight_arrival_counts_decode_time():
    """e2e-SLO arrivals need prefill + remaining-decode inside the
    budget: 2.0 s covers 0.5 + 4x0.25 only if admitted immediately, so
    the policy must evict rather than wait."""
    slow = _req(0, 10, 100, SLO(e2e=1e6))
    tight = _req(1, 8, 5, SLO(e2e=2.0), arrival=1.0)
    pre = simulate([slow, tight], CONST, 1,
                   make("slo-preempt", model=CONST))
    assert pre.n_preempted == 1 and pre.met[1]


def test_preempt_skips_doomed_e2e_arrival():
    """An e2e budget that cannot even cover prefill + decode must not
    cost a healthy victim its KV (no-thrash guard, e2e flavor)."""
    slow = _req(0, 10, 100, SLO(e2e=1e6))
    doomed = _req(1, 8, 5, SLO(e2e=1.0), arrival=1.0)   # needs 1.5 s
    pre = simulate([slow, doomed], CONST, 1,
                   make("slo-preempt", model=CONST))
    assert pre.n_preempted == 0


def test_preempt_prices_chunked_prefill_honestly():
    """Under ChunkedPrefill the time-to-first-token includes the decode
    rounds interleaved between chunks; an arrival savable under stalling
    prefill may be doomed under chunking and must not cost a victim."""
    def workload():
        runners = [_req(i, 10, 200, SLO(e2e=1e6)) for i in range(2)]
        return runners + [_req(2, 32, 2, SLO(ttft=0.75), arrival=1.0)]
    pol = make("slo-preempt", model=PROP)
    # chunked: 4 chunks x 0.08 + 3 decode rounds x 0.25 = 1.07 s > 0.75
    c = simulate(workload(), PROP, 2, pol, discipline="chunked:8")
    assert c.n_preempted == 0
    # stalling: 0.32 s prefill fits the budget -> eviction pays off
    s = simulate(workload(), PROP, 2, pol, discipline="stall")
    assert s.n_preempted == 1 and s.met[2]


def test_victim_guard_accounts_for_other_urgent_pending():
    """A victim must absorb the service of EVERY deadline-bearing
    pending request (they all re-queue ahead of it), not just the
    triggering arrival's — else eviction turns a met SLO into a miss."""
    victim = _req(0, 10, 400, SLO(e2e=115.0))     # met if left alone
    big = _req(1, 2800, 2, SLO(ttft=29.0), arrival=1.0)   # 28 s prefill
    small = _req(2, 8, 2, SLO(ttft=40.0), arrival=1.0)
    sim = simulate([victim, big, small], PROP, 1,
                   make("slo-preempt", model=PROP))
    assert sim.n_preempted == 0
    assert sim.met[0]              # victim never sacrificed into a miss


def test_make_rejects_suffix_for_suffixless_keys():
    with pytest.raises(ValueError):
        make("stall:32")
    with pytest.raises(ValueError):
        make("fcfs:1")


def test_preempt_accounts_consumed_wait_capacity():
    """Regression: with two tight arrivals and only one soon-to-finish
    slot, the second arrival must not be judged against the first slot's
    wait (already claimed) — it needs its own eviction."""
    a0 = _req(0, 10, 10, SLO(e2e=5.0))        # finishes soon, low slack
    a1 = _req(1, 10, 200, SLO(e2e=1e6))       # long, huge slack
    b0 = _req(2, 8, 2, SLO(ttft=3.5), arrival=1.0)
    b1 = _req(3, 8, 2, SLO(ttft=3.6), arrival=1.0)
    pre = simulate([a0, a1, b0, b1], CONST, 2,
                   make("slo-preempt", model=CONST))
    # b0 waits for a0's slot; b1 gets one via evicting a1 — everyone met
    assert pre.preemptions == {1: 1}
    assert pre.attainment == 1.0


def test_requeued_request_ttft_constraint_is_settled():
    """Regression: a re-queued preempted request already emitted its
    first token, so its (long-expired) TTFT budget must not mark it
    doomed — its live e2e deadline can still earn eviction assistance."""
    pol = make("slo-preempt", model=CONST)
    victim = _req(0, 10, 100, SLO(e2e=1e6))
    active = (make_active_view(victim, 10, 90, 20, 50.0, 0.5, 0.0, 1,
                               CONST),)
    rq = Request(1, "chat", 8, SLO(ttft=1.0, e2e=60.0), output_len=10)
    rq.submit_time = 0.0                    # waited 50 s: TTFT long dead
    view = SchedulerView(pending=(rq,), active=active, now=50.0, free=0,
                         max_batch=1, pending_generated=(5,))
    dec = pol.decide(view)
    assert dec.preempt == [0] and dec.admit == [0]
    # ...but a FRESH request whose TTFT budget is already blown (and
    # whose e2e cannot be saved either) stays classified as doomed
    fresh = Request(2, "chat", 8, SLO(ttft=1.0, e2e=49.5), output_len=10)
    fresh.submit_time = 0.0
    view2 = SchedulerView(pending=(fresh,), active=active, now=50.0,
                          free=0, max_batch=1, pending_generated=(0,))
    assert pol.decide(view2).preempt == []


def test_decision_indices_are_sanitized():
    """Duplicate / out-of-range admit and preempt indices from a custom
    policy must not drop or double-admit requests (normalize_decision)."""
    class Sloppy(SchedulingPolicy):
        def decide(self, view):
            return Decision(admit=[0, 0, 1, -3, 99],
                            preempt=[-1, 99])
    reqs = [_req(i, 10, 3) for i in range(2)]
    sim = simulate(reqs, CONST, 4, Sloppy(), respect_arrivals=False)
    assert sim.n == 2
    assert sim.n_preempted == 0          # bogus preempt indices ignored
    assert sim.ttft[0] == sim.ttft[1] == pytest.approx(0.5)


# ----------------------------------------------------- chunked discipline
def test_chunked_core_decodes_advance_between_chunks():
    """Acceptance: the event core reproduces ChunkedPrefill semantics —
    running decodes advance between prefill chunks.  Exact timeline under
    PROP (prefill 0.01 s/token, decode 0.25 s/token), chunk=8:
    req1 (l_i=32) prefills in 4 chunks with req0 decoding in between."""
    reqs = [_req(0, 8, 5), _req(1, 32, 2, arrival=0.1)]
    c = simulate(reqs, PROP, 2, "fcfs", discipline="chunked:8")
    assert c.ttft[0] == pytest.approx(0.08)
    # req0: 3 decodes interleaved with req1's chunks, finishes during them
    assert c.e2e[0] == pytest.approx(1.32)
    # req1 TTFT: 4 chunks x 0.08 + 3 interleaved decode rounds, - arrival
    assert c.ttft[1] == pytest.approx(0.08 * 4 + 3 * 0.25 + 0.33 - 0.1)
    assert c.e2e[1] == pytest.approx(c.ttft[1] + 0.25)
    # vs stalling: req0's decodes stall for req1's whole 0.32 s prefill
    s = simulate([_req(0, 8, 5), _req(1, 32, 2, arrival=0.1)], PROP, 2,
                 "fcfs", discipline="stall")
    assert c.e2e[0] < s.e2e[0]
    assert s.ttft[1] < c.ttft[1]        # stall favors the newcomer


def test_chunked_single_request_equals_stall_when_one_chunk():
    """chunk >= l_i degenerates to whole-prompt prefill timings."""
    a = simulate([_req(0, 10, 5)], CONST, 4, "fcfs", discipline="stall")
    b = simulate([_req(0, 10, 5)], CONST, 4, "fcfs",
                 discipline=ChunkedPrefill(16))
    assert a.e2e[0] == pytest.approx(b.e2e[0])
    assert a.ttft[0] == pytest.approx(b.ttft[0])


# ------------------------------------------------------- planned + reuse
def test_planned_policy_is_reusable_across_runs():
    reqs = [_req(i, 10, 3) for i in range(4)]
    pol = PlannedPolicy([reqs[:2], reqs[2:]])
    a = simulate(reqs, CONST, 4, pol, respect_arrivals=False)
    b = simulate(reqs, CONST, 4, pol, respect_arrivals=False)
    assert a.n == b.n == 4
    assert a.e2e == b.e2e and a.ttft == b.ttft


# --------------------------------------------------------------- registry
def test_registry_make():
    assert isinstance(make("fcfs"), FCFSPolicy)
    assert isinstance(make("priority"), FCFSPolicy)
    assert isinstance(make("slo-reanneal", model=CONST, max_batch=4),
                      SLOReannealPolicy)
    pre = make("slo-preempt", model=CONST)
    assert isinstance(pre, SLOPreemptPolicy) and pre.preemptive
    assert isinstance(make("planned", batches=[[0]]), PlannedPolicy)
    assert make("chunked:32").chunk_size == 32
    assert make("chunked", chunk_size=16).chunk_size == 16
    assert make("chunked").chunk_size == 64
    assert make("stall").chunk_size == 0
    assert isinstance(make_discipline(None), StallingPrefill)
    d = ChunkedPrefill(8)
    assert make(d) is d and make_discipline(d) is d
    with pytest.raises(ValueError):
        make("no-such-policy")
    with pytest.raises(ValueError):
        make("slo-reanneal")                # missing model/max_batch
    with pytest.raises(ValueError):
        ChunkedPrefill(0)
    with pytest.raises(TypeError):
        make_discipline("fcfs")             # a policy, not a discipline


def test_admission_policy_shim_still_runs():
    """v1 subclasses (select-only) are adapted into decide() and warn."""
    with pytest.warns(DeprecationWarning):
        class TailFirst(AdmissionPolicy):
            def select(self, pending, now, free, active_count):
                return list(range(len(pending)))[::-1]
    reqs = [_req(i, 10, 3) for i in range(4)]
    sim = simulate(reqs, CONST, 2, TailFirst(), respect_arrivals=False)
    assert sim.n == 4
    # tail-first admission: req 3 gets the first prefill slot
    assert sim.ttft[3] == pytest.approx(0.5)

    class DuckSelect:                       # duck-typed, not a subclass
        def select(self, pending, now, free, active_count):
            return list(range(min(free, len(pending))))
    with pytest.warns(DeprecationWarning):
        pol = as_scheduling_policy(DuckSelect())
    sim2 = simulate(reqs, CONST, 2, pol, respect_arrivals=False)
    assert sim2.n == 4


def test_v2_policy_objects_shared_by_core_signature():
    """Native v2 policies raise no deprecation warnings and pass through
    as_scheduling_policy unchanged."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        class Mine(SchedulingPolicy):
            def decide(self, view):
                return Decision(admit=list(range(min(view.free,
                                                     len(view.pending)))))
        pol = Mine()
        assert as_scheduling_policy(pol) is pol
        sim = simulate([_req(0, 10, 3)], CONST, 2, pol,
                       respect_arrivals=False)
    assert sim.n == 1


# ------------------------------------------------- clock-mismatch (unit)
def test_with_remaining_slo_honors_submit_time():
    """Regression: waited time must be computed on one clock.  A warm
    executor clock (now=100) with a workload-relative arrival (0) used to
    collapse every budget; submit_time fixes the origin."""
    r = Request(0, "chat", 10, SLO(ttft=5.0, tpot=0.1), arrival_time=0.0)
    bad = with_remaining_slo(r, 100.0)       # fallback: arrival clock
    assert bad.slo.ttft == pytest.approx(-95.0)
    r.submit_time = 100.0
    assert submit_base(r) == 100.0
    good = with_remaining_slo(r, 100.0)      # same clock -> zero waited
    assert good.slo.ttft == pytest.approx(5.0)
    assert good.slo.tpot == pytest.approx(0.1)   # tpot never shifted
    later = with_remaining_slo(r, 102.5)
    assert later.slo.ttft == pytest.approx(2.5)


def test_core_stamps_submit_time_on_its_clock():
    """The event core stamps submit_time at release so policies always
    see a single clock, even for requests previously run elsewhere."""
    r = _req(0, 10, 3, arrival=2.0)
    r.submit_time = 12345.0                  # stale stamp from another run
    sim = simulate([r], CONST, 2, "fcfs")
    assert r.submit_time == pytest.approx(2.0)
    assert sim.ttft[0] == pytest.approx(0.5)  # arrival-relative


# =================================== properties (index-policy family)
# hypothesis is optional (pyproject's dev extra): when installed it
# drives these properties over a wide random search; when absent the
# SAME checks run over a fixed seeded sweep instead of skipping, so the
# invariants stay enforced on minimal installs.
import dataclasses

from repro.core import IndexPolicy
from repro.core.policies import normalize_decision

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False


def _seeded_property(fn):
    if _HAVE_HYPOTHESIS:
        return settings(max_examples=60, deadline=None)(
            given(seed=st.integers(0, 2**31 - 1))(fn))
    return pytest.mark.parametrize("seed", range(40))(fn)


def _rand_view(rng, paged=False):
    """A random but well-formed SchedulerView: mixed SLO kinds (e2e /
    ttft+tpot / none), some re-queued pending entries, a partly full
    batch, and optionally paged-pool state."""
    n = int(rng.integers(1, 9))
    pending = []
    for i in range(n):
        coin = rng.random()
        if coin < 0.4:
            slo = SLO(e2e=float(rng.uniform(0.2, 60.0)))
        elif coin < 0.8:
            slo = SLO(ttft=float(rng.uniform(0.02, 10.0)),
                      tpot=float(rng.uniform(0.01, 0.5)))
        else:
            slo = SLO()                       # no deadline -> tier 1
        r = Request(i, "chat", int(rng.integers(1, 300)), slo,
                    output_len=int(rng.integers(1, 200)))
        r.predicted_output_len = r.output_len
        r.submit_time = 0.0
        pending.append(r)
    gen = tuple(int(rng.integers(0, 4)) if rng.random() < 0.25 else 0
                for _ in range(n))
    now = float(rng.uniform(0.0, 5.0))
    max_batch = int(rng.integers(1, 7))
    na = int(rng.integers(0, max_batch + 1))
    active = []
    for j in range(na):
        r = Request(1000 + j, "chat", int(rng.integers(1, 200)),
                    SLO(e2e=float(rng.uniform(1.0, 120.0))),
                    output_len=int(rng.integers(2, 100)))
        g = int(rng.integers(1, r.output_len))
        active.append(make_active_view(
            r, g, r.output_len - g, r.input_len + g, now,
            float(rng.uniform(0.0, now)) if rng.random() < 0.8 else None,
            0.0, max(na, 1), PAPER_TABLE2))
    kw = {}
    if paged:
        kw = dict(free_blocks=int(rng.integers(0, 48)), total_blocks=64,
                  block_size=int(rng.integers(1, 33)),
                  pages_per_slot=int(rng.integers(1, 9)))
    return SchedulerView(pending=tuple(pending), active=tuple(active),
                         now=now, free=max_batch - na,
                         max_batch=max_batch, pending_generated=gen, **kw)


@_seeded_property
def test_index_admission_is_permutation_invariant(seed):
    """Which requests an IndexPolicy admits (and in what order) depends
    only on the request set — never on the order the executor happens
    to list the queue in (ties break on req_id)."""
    rng = np.random.default_rng(seed)
    view = _rand_view(rng, paged=bool(rng.random() < 0.5))
    mode = ("w", "sjf", "edf")[int(rng.integers(0, 3))]
    pol = IndexPolicy(PAPER_TABLE2, mode=mode)
    base = [view.pending[i].req_id for i in pol.decide(view).admit]
    perm = rng.permutation(len(view.pending))
    shuffled = dataclasses.replace(
        view,
        pending=tuple(view.pending[j] for j in perm),
        pending_generated=tuple(view.pending_generated[j] for j in perm))
    got = [shuffled.pending[i].req_id
           for i in pol.decide(shuffled).admit]
    assert got == base


@_seeded_property
def test_index_paged_admission_never_exceeds_free_blocks(seed):
    """On a paged view the admitted set fits the block pool as priced by
    the view's own pending_blocks (and never exceeds free slots)."""
    rng = np.random.default_rng(seed)
    view = _rand_view(rng, paged=True)
    mode = ("w", "sjf", "edf")[int(rng.integers(0, 3))]
    pol = IndexPolicy(PAPER_TABLE2, mode=mode)
    admit, _ = normalize_decision(pol.decide(view), view)
    assert len(admit) <= max(view.free, 0)
    assert sum(view.pending_blocks(i) for i in admit) <= view.free_blocks


@_seeded_property
def test_normalize_decision_is_idempotent(seed):
    """Sanitizing a sanitized decision is a fixed point: dedup,
    bounds-checks, and the reverse-sorted preempt order all survive a
    second pass unchanged."""
    rng = np.random.default_rng(seed)
    view = _rand_view(rng, paged=bool(rng.random() < 0.5))
    raw = Decision(
        admit=[int(rng.integers(-4, len(view.pending) + 4))
               for _ in range(int(rng.integers(0, 12)))],
        preempt=[int(rng.integers(-4, len(view.active) + 4))
                 for _ in range(int(rng.integers(0, 8)))])
    a1, p1 = normalize_decision(raw, view)
    a2, p2 = normalize_decision(Decision(admit=a1, preempt=p1), view)
    assert (a2, p2) == (a1, p1)
    assert len(set(a1)) == len(a1) and len(set(p1)) == len(p1)
    assert all(0 <= j < len(view.pending) for j in a1)
    assert all(0 <= j < len(view.active) for j in p1)
    assert p1 == sorted(p1, reverse=True)


# ===================================================== engine (JAX) side
jax = pytest.importorskip("jax")


@pytest.fixture(scope="module")
def tiny():
    from repro.models import ModelConfig, init_params
    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                      dtype="float32")
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def _rts(specs, vocab=128, seed=0):
    """specs: list of (li, max_new, slo, arrival)."""
    from repro.engine.request import RuntimeRequest
    rng = np.random.default_rng(seed)
    out = []
    for i, (li, lo, slo, arr) in enumerate(specs):
        r = Request(i, "chat", li, slo, output_len=lo, arrival_time=arr)
        r.predicted_output_len = lo
        out.append(RuntimeRequest(
            request=r,
            prompt_tokens=rng.integers(0, vocab, li).astype(np.int32),
            max_new_tokens=lo))
    return out


def test_engine_preemption_observable(tiny):
    """Acceptance: the same SLOPreemptPolicy object produces observable
    preemption in the engine — a tight late arrival is served ahead of a
    large-slack running request, whose KV is recomputed honestly."""
    from repro.engine.engine import Engine
    specs = [(12, 40, SLO(e2e=1e6), 0.0),           # long, huge slack
             (8, 3, SLO(ttft=0.2), 0.001)]          # tight late arrival
    rts_f = _rts(specs)
    fcfs = Engine(tiny[0], tiny[1], max_slots=1, max_seq_len=128) \
        .run_policy(rts_f, "fcfs", respect_arrivals=True)
    # queueing delay counts from the true arrival instant, not release
    assert rts_f[1].submit_time == pytest.approx(0.001)
    pol = SLOPreemptPolicy(PAPER_TABLE2)
    pre = Engine(tiny[0], tiny[1], max_slots=1, max_seq_len=128) \
        .run_policy(_rts(specs), pol, model=PAPER_TABLE2,
                    respect_arrivals=True)
    # preemption happened, and only where expected
    assert pre[0]["preemptions"] >= 1 and pre[1]["preemptions"] == 0
    assert all(v["preemptions"] == 0 for v in fcfs.values())
    # every request still completes fully after the KV recompute
    assert len(pre[0]["tokens"]) == 40 and len(pre[1]["tokens"]) == 3
    assert len(fcfs[0]["tokens"]) == 40
    # the tight arrival jumped the queue: it finishes before the long
    # request, and earlier than under FCFS (which drains 0 first).
    # NOTE: wall-clock ratios and met-flags are timing-flaky on a loaded
    # CPU; the deterministic met-under-preempt / miss-under-FCFS
    # acceptance lives in test_preempt_core_tight_arrival_meets_slo.
    assert pre[1]["e2e"] < pre[0]["e2e"]
    assert fcfs[1]["ttft"] > pre[1]["ttft"]
    assert fcfs[1]["e2e"] > fcfs[0]["e2e"]     # FCFS: 1 waited behind 0


def test_engine_core_chunked_parity(tiny):
    """Acceptance: same workload + same ChunkedPrefill discipline through
    the engine and the event core — TTFT/e2e orderings and met flags
    agree (the chunked analog of the PR-1 drift fix)."""
    from repro.core import fit
    from repro.core.profiler import LatencyProfiler
    from repro.engine.engine import Engine
    met_slo = SLO(ttft=1e6, tpot=1e6)
    miss_slo = SLO(e2e=1e-9)
    specs = [(24, 2, met_slo, 0.0), (9, 12, miss_slo, 0.0),
             (30, 4, met_slo, 0.0), (17, 8, miss_slo, 0.0)]
    # fit the latency model from this engine's own behaviour
    prof = LatencyProfiler()
    warm = Engine(tiny[0], tiny[1], max_slots=2, max_seq_len=128,
                  profiler=prof)
    warm.run_fcfs(_rts(specs))
    model = prof.fit()
    disc = ChunkedPrefill(8)
    eng = Engine(tiny[0], tiny[1], max_slots=2, max_seq_len=128)
    out = eng.run_fcfs(_rts(specs), discipline=disc)
    sim = simulate([rt.request for rt in _rts(specs)], model, 2, "fcfs",
                   discipline=disc, respect_arrivals=False)

    def order(d):
        return sorted(d, key=lambda k: d[k])
    assert order({k: v["ttft"] for k, v in out.items()}) == order(sim.ttft)
    assert order({k: v["e2e"] for k, v in out.items()}) == order(sim.e2e)
    assert {k: v["met"] for k, v in out.items()} == sim.met


def test_engine_warm_clock_keeps_slo_budgets(tiny):
    """Regression (clock mismatch): on a warm engine, SLO budgets must be
    shifted by time waited on the ENGINE clock (via submit_time), not by
    engine-clock-minus-workload-arrival."""
    from repro.engine.engine import Engine

    class Probe(SLOReannealPolicy):
        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            self.views = []

        def decide(self, view):
            self.views.append(view)
            return super().decide(view)

    eng = Engine(tiny[0], tiny[1], max_slots=2, max_seq_len=128)
    eng.run_fcfs(_rts([(10, 4, SLO(ttft=1e6, tpot=1e6), 0.0)] * 2))
    warm_clock = eng.clock
    assert warm_clock > 0                   # the heart of the regression
    probe = Probe(PAPER_TABLE2, 2, SAParams(seed=0))
    out = eng.run_policy(_rts([(10, 3, SLO(ttft=5.0, tpot=10.0), 0.0)] * 4,
                              seed=1), probe)
    assert len(out) == 4
    v = probe.views[0]
    assert v.now >= warm_clock
    for r in v.pending:
        assert r.submit_time is not None and r.submit_time >= warm_clock
        # with the bug, waited == engine clock and this went negative
        shifted = with_remaining_slo(r, v.now)
        assert shifted.slo.ttft == pytest.approx(
            5.0 - (v.now - r.submit_time), abs=1e-9)
        assert shifted.slo.ttft > 4.0
