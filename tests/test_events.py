"""Unified discrete-event core: wrapper routing, engine-faithful
first-token accounting (regression for the historical simulator/engine
drift), multi-instance online scheduling, incremental-ΔG agreement, and
the annealer's symmetric early exits."""
import random

import numpy as np
import pytest

from repro.core import (PAPER_TABLE2, FCFSPolicy, IncrementalEvaluator,
                        PlannedPolicy, SAParams, as_arrays, evaluate,
                        fcfs_schedule, priority_mapping, run_fcfs_continuous,
                        run_planned, run_priority_continuous, simulate)
from repro.core.annealing import _to_arrays, _to_batches, apply_move, \
    propose_move
from repro.core.latency_model import LinearLatencyModel
from repro.core.online import simulate_online
from repro.core.slo import SLO, Request
from repro.data.synthetic import sample_requests

# prefill = 0.5 s, per-token decode = 0.25 s (b- and length-independent)
CONST = LinearLatencyModel(0, 0, 0, 0.5, 0, 0, 0, 0.25)
# per-token decode = current context length (exposes the accum trajectory)
ACCUM = LinearLatencyModel(0, 0, 0, 0.5, 0, 0, 1.0, 0)


def _req(i, li, lo, slo=None, arrival=0.0):
    return Request(i, "chat", li, slo or SLO(ttft=1e6, tpot=1e6),
                   output_len=lo, arrival_time=arrival)


# ------------------------------------------------------- token accounting
def test_first_token_comes_from_prefill():
    """TTFT is the first token, so lo=5 needs exactly 4 decode rounds and
    TPOT divides by all 5 generated tokens (engine semantics)."""
    sim = run_fcfs_continuous([_req(0, 10, 5)], CONST, max_batch=4)
    assert sim.ttft[0] == pytest.approx(0.5)
    assert sim.e2e[0] == pytest.approx(0.5 + 4 * 0.25)
    assert sim.tpot[0] == pytest.approx((sim.e2e[0] - sim.ttft[0]) / 5)


def test_single_token_request_finishes_at_prefill():
    sim = run_fcfs_continuous([_req(0, 10, 1)], CONST, max_batch=4)
    assert sim.e2e[0] == pytest.approx(sim.ttft[0]) == pytest.approx(0.5)
    assert sim.tpot[0] == 0.0


def test_decode_context_starts_after_first_token():
    """Decode rounds see context l_i + gen: for li=10, lo=5 the per-token
    times are 11+12+13+14 (not 10..13, the pre-unification off-by-one)."""
    sim = run_fcfs_continuous([_req(0, 10, 5)], ACCUM, max_batch=4)
    assert sim.e2e[0] - sim.ttft[0] == pytest.approx(11 + 12 + 13 + 14)


def test_engine_first_token_accounting_matches_core():
    """Regression: the real engine and the event core agree that a request
    with l_o generated tokens runs l_o - 1 decode rounds after prefill."""
    jax = pytest.importorskip("jax")
    from repro.core.profiler import LatencyProfiler
    from repro.engine.engine import Engine
    from repro.engine.request import RuntimeRequest
    from repro.models import ModelConfig, init_params
    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                      dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prof = LatencyProfiler()
    eng = Engine(cfg, params, max_slots=1, max_seq_len=128, profiler=prof)
    rng = np.random.default_rng(0)
    rt = RuntimeRequest(request=_req(0, 12, 5),
                        prompt_tokens=rng.integers(0, 128, 12).astype(
                            np.int32),
                        max_new_tokens=5)
    out = eng.run_fcfs([rt])[0]
    assert len(out["tokens"]) == 5
    assert len(prof.prefill_samples) == 1
    assert len(prof.decode_samples) == 4          # lo - 1 decode rounds
    assert out["tpot"] == pytest.approx((out["e2e"] - out["ttft"]) / 5)
    # the event core, driven by the same numbers, mirrors the structure
    sim = run_fcfs_continuous([_req(0, 12, 5)], CONST, max_batch=1)
    assert (sim.e2e[0] - sim.ttft[0]) / 0.25 == pytest.approx(4)


# --------------------------------------------------------- wrapper routing
def test_priority_continuous_equals_fcfs_on_flat_order():
    reqs = sample_requests(12, seed=0)
    for r in reqs:
        r.predicted_output_len = r.output_len
    batches = [reqs[i:i + 3] for i in range(0, 12, 3)]
    a = run_priority_continuous(batches, PAPER_TABLE2, 3)
    b = run_fcfs_continuous([r for bt in batches for r in bt],
                            PAPER_TABLE2, 3)
    assert a.e2e == b.e2e and a.ttft == b.ttft and a.met == b.met


def test_planned_barrier_between_batches():
    reqs = [_req(i, 10, 3) for i in range(4)]
    sim = run_planned([reqs[:2], reqs[2:]], CONST, inter_batch_gap=0.0)
    end_b1 = max(sim.e2e[0], sim.e2e[1])
    start_b2 = min(sim.ttft[2], sim.ttft[3]) - 0.5   # minus prefill
    assert start_b2 == pytest.approx(end_b1)


def test_planned_raises_when_batch_exceeds_slots():
    reqs = [_req(i, 10, 3) for i in range(3)]
    with pytest.raises(RuntimeError):
        simulate(reqs, CONST, 2, PlannedPolicy([[0, 1, 2]]),
                 respect_arrivals=False)


# ------------------------------------------------------------ multi-instance
def test_multi_instance_online_completes_and_speeds_up():
    rng = np.random.default_rng(5)
    reqs = sample_requests(20, seed=6)
    t = 0.0
    for r in reqs:
        t += rng.exponential(0.2)
        r.arrival_time = t
        r.predicted_output_len = r.output_len
    one = simulate_online(reqs, PAPER_TABLE2, 4, "fcfs")
    two = simulate_online(reqs, PAPER_TABLE2, 4, "fcfs", num_instances=2)
    assert one.n == two.n == 20
    mk1 = max(one.e2e[r.req_id] + r.arrival_time for r in reqs)
    mk2 = max(two.e2e[r.req_id] + r.arrival_time for r in reqs)
    assert mk2 <= mk1 * 1.01
    # re-annealed admission also runs multi-instance
    slo2 = simulate_online(reqs, PAPER_TABLE2, 4, "slo",
                           SAParams(seed=0), num_instances=2)
    assert slo2.n == 20
    assert slo2.attainment >= two.attainment * 0.9


def test_idle_instance_does_not_deadlock():
    # 1 request, 3 instances: two instances never get work
    sim = simulate([_req(0, 10, 3, arrival=1.0)], CONST, 2, "fcfs",
                   num_instances=3)
    assert sim.n == 1
    assert sim.ttft[0] == pytest.approx(0.5)      # arrival-relative


# --------------------------------------------------- incremental ΔG (unit)
def _agreement_run(reqs, max_batch, seed, steps=60):
    arrays = as_arrays(reqs)
    n = len(reqs)
    inc = IncrementalEvaluator(arrays, PAPER_TABLE2,
                               _to_batches(*fcfs_schedule(n, max_batch)))
    rng = random.Random(seed)
    checked = 0
    for _ in range(steps):
        move = propose_move(inc.batches, max_batch, rng)
        if move is None:
            continue
        g, n_met, staged = inc.preview(move)
        cand = apply_move(inc.batches, move)
        assert cand == staged[0]
        ev = evaluate(arrays, PAPER_TABLE2, *_to_arrays(cand))
        assert abs(ev.G - g) <= 1e-9 * max(1.0, abs(ev.G))
        assert ev.n_met == n_met
        checked += 1
        if rng.random() < 0.5:
            inc.commit(staged)
    assert checked > 10


def test_incremental_matches_evaluate_h1_only():
    rng = np.random.default_rng(0)
    reqs = [Request(i, "code", int(rng.integers(16, 900)),
                    SLO(e2e=float(rng.uniform(1, 40))),
                    output_len=int(rng.integers(4, 500)))
            for i in range(18)]
    for seed in range(3):
        _agreement_run(reqs, 4, seed)


def test_incremental_matches_evaluate_h0_only():
    rng = np.random.default_rng(1)
    reqs = [Request(i, "chat", int(rng.integers(16, 900)),
                    SLO(ttft=float(rng.uniform(0.5, 15)),
                        tpot=float(rng.uniform(0.01, 0.3))),
                    output_len=int(rng.integers(4, 500)))
            for i in range(18)]
    for seed in range(3):
        _agreement_run(reqs, 3, seed)


def test_incremental_matches_evaluate_mixed():
    reqs = sample_requests(22, seed=9)
    for r in reqs:
        r.predicted_output_len = r.output_len
    for seed in range(3):
        _agreement_run(reqs, 5, seed)


def test_delay_on_singleton_last_batch_is_noop():
    """Regression: delaying the only member of the last batch must not
    leave an empty batch behind (its -inf duration would zero all
    downstream waits and mark everything met)."""
    reqs = [_req(i, 20, 10, SLO(ttft=0.01, tpot=1e-9)) for i in range(3)]
    arrays = as_arrays(reqs)
    inc = IncrementalEvaluator(arrays, PAPER_TABLE2, [[0, 1], [2]])
    move = ("delay", 1, 0)
    g, n_met, staged = inc.preview(move)
    assert staged[0] == [[0, 1], [2]] == apply_move([[0, 1], [2]], move)
    ev = evaluate(arrays, PAPER_TABLE2, *_to_arrays(staged[0]))
    assert (g, n_met) == (ev.G, ev.n_met)


def test_incremental_matches_evaluate_zero_output_len():
    """Regression: ``model.tpot`` clamps l_o to 1 before recomputing the
    decode time, so a l_o=0 request's TPOT is NOT zero — the incremental
    coefficients must clamp identically or h=0 met-flags diverge."""
    reqs = [
        Request(0, "chat", 100, SLO(ttft=10.0, tpot=1e-6), output_len=0),
        Request(1, "chat", 50, SLO(ttft=10.0, tpot=1.0), output_len=0),
        Request(2, "code", 80, SLO(e2e=30.0), output_len=0),
        Request(3, "chat", 60, SLO(ttft=5.0, tpot=0.05), output_len=7),
    ]
    arrays = as_arrays(reqs)
    perm, bid = fcfs_schedule(4, 2)
    inc = IncrementalEvaluator(arrays, PAPER_TABLE2, _to_batches(perm, bid))
    ev = evaluate(arrays, PAPER_TABLE2, perm, bid)
    assert inc.n_met == ev.n_met
    assert abs(inc.G - ev.G) <= 1e-9 * max(1.0, abs(ev.G))
    _agreement_run(reqs, 2, 0, steps=40)


# --------------------------------------------------------- annealer exits
def test_fcfs_start_early_exit():
    """Symmetric line-7 check: the e2e-sorted start misses an SLO but the
    FCFS order meets every SLO → the annealer must return it immediately."""
    model = LinearLatencyModel(0, 0, 1.0, 0, 0, 0, 0, 1.0)
    reqs = [
        Request(0, "chat", 10, SLO(ttft=10.5, tpot=2.0), output_len=1),
        Request(1, "chat", 1, SLO(ttft=20.0, tpot=2.0), output_len=5),
    ]
    arrays = as_arrays(reqs)
    res = priority_mapping(arrays, model, 1, SAParams(seed=0))
    assert res.early_exit
    assert res.perm.tolist() == [0, 1]            # the FCFS order
    assert evaluate(arrays, model, res.perm, res.batch_id).n_met == 2


def test_mid_anneal_early_exit_when_all_met():
    """Paper Fig. 3 workload: neither start meets all SLOs, but the
    SLO-aware order does — the anneal stops as soon as it finds it."""
    model = LinearLatencyModel(0, 0, 0, 0, 0, 0, 0, 1e-3)
    reqs = [
        Request(0, "code", 1, SLO(e2e=0.8), output_len=300),
        Request(1, "code", 1, SLO(e2e=0.5), output_len=500),
        Request(2, "code", 1, SLO(e2e=1.8), output_len=800),
    ]
    arrays = as_arrays(reqs)
    res = priority_mapping(arrays, model, 1, SAParams(seed=0))
    assert res.early_exit
    assert evaluate(arrays, model, res.perm, res.batch_id).n_met == 3


def test_saparams_default_is_none_sentinel():
    """Regression for the shared-mutable-default bug: one module-level
    SAParams() instance used to be shared across every caller."""
    import inspect

    from repro.core.scheduler import SLOAwareScheduler
    assert inspect.signature(priority_mapping) \
        .parameters["params"].default is None
    assert inspect.signature(SLOAwareScheduler.__init__) \
        .parameters["sa_params"].default is None
    s1 = SLOAwareScheduler(PAPER_TABLE2)
    s2 = SLOAwareScheduler(PAPER_TABLE2)
    assert s1.sa_params is not s2.sa_params


def test_incremental_and_oracle_paths_reach_same_quality():
    reqs = sample_requests(14, seed=21)
    import dataclasses
    for r in reqs:
        r.slo = dataclasses.replace(
            r.slo,
            e2e=r.slo.e2e * 0.2 if r.slo.e2e else None,
            ttft=r.slo.ttft * 0.02 if r.slo.ttft else None,
            tpot=r.slo.tpot * 0.5 if r.slo.tpot else None)
        r.predicted_output_len = r.output_len
    arrays = as_arrays(reqs)
    ri = priority_mapping(arrays, PAPER_TABLE2, 4, SAParams(seed=3))
    rf = priority_mapping(arrays, PAPER_TABLE2, 4,
                          SAParams(seed=3, incremental=False))
    # identical rng trajectory + scoring that agrees to ~1e-15 ⇒ the two
    # paths walk the same accept/reject sequence
    assert ri.perm.tolist() == rf.perm.tolist()
    assert ri.batch_id.tolist() == rf.batch_id.tolist()
    assert ri.G == pytest.approx(rf.G, abs=1e-12)
