"""Shared-prefix KV reuse: refcounted pool semantics (incl. the
hardened PR-5 ``free`` shim), the radix prefix index (match/insert/LRU
eviction), lifecycle invariants under admit -> share -> preempt ->
re-admit -> finish, aliased-prefix logits parity vs dense, zero prefill
FLOPs for the shared span, copy-on-write, the equal-HBM concurrency
win, and the prefill discount through both annealer backends."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

import repro.core.annealing_jax as aj
from repro.core.latency_model import PAPER_TABLE2
from repro.core.objective import calculate_g, fcfs_schedule, \
    linear_request_coefs
from repro.core.slo import SLO, Request, as_arrays
from repro.data.synthetic import (sample_multiturn_requests,
                                  sample_multiturn_token_requests)
from repro.engine.blocks import BlockPool
from repro.engine.engine import Engine
from repro.engine.prefix import RadixPrefixIndex
from repro.engine.request import RuntimeRequest
from repro.models import ModelConfig, init_cache, init_params
from repro.models.cache import (copy_page, init_paged_cache,
                                paged_slot_len)
from repro.models.model import forward_chunk_paged, forward_full, \
    forward_prefill_paged

CFG = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                  dtype="float32")
P = 16          # block size used throughout


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _rt(prompt, rid=0, max_new=4):
    return RuntimeRequest(
        request=Request(req_id=rid, task_type="chat", input_len=len(prompt),
                        output_len=max_new, slo=SLO(ttft=100.0, tpot=10.0)),
        prompt_tokens=np.asarray(prompt, np.int32), max_new_tokens=max_new)


# --------------------------------------------------------------- block pool
def test_pool_refcount_lifecycle():
    pool = BlockPool(8)
    a = pool.alloc(3)
    assert all(pool.refcount(i) == 1 for i in a)
    assert pool.in_use == 3 and pool.available == 4 and pool.shared == 0
    pool.share(a[:2])
    assert pool.shared == 2 and pool.refcount(a[0]) == 2
    pool.release(a)                     # one owner off each: a[2] frees
    assert pool.in_use == 2 and pool.available == 5
    pool.release(a[:2])                 # last owners: pool drains
    assert pool.in_use == 0 and pool.available == pool.total == 7


def test_pool_share_validates_before_mutating():
    pool = BlockPool(8)
    a = pool.alloc(2)
    with pytest.raises(ValueError, match="not allocated"):
        pool.share([a[0], 99])
    assert pool.refcount(a[0]) == 1     # nothing incremented


def test_pool_release_validates_multiplicity_atomically():
    pool = BlockPool(8)
    a = pool.alloc(1)
    with pytest.raises(ValueError, match="double free"):
        pool.release([a[0], a[0]])      # refcount 1 can't cover x2
    assert pool.refcount(a[0]) == 1 and pool.available == 6


def test_pool_free_shim_rejects_duplicates_and_double_free():
    """The PR-5 API hardened: duplicate ids in one call and double frees
    raise *before* any mutation (the old free() appended to the free
    list mid-walk, so a duplicate corrupted it)."""
    pool = BlockPool(8)
    a = pool.alloc(2)
    with pytest.raises(ValueError, match="listed twice"):
        pool.free([a[0], a[0]])
    # atomic: the failed call freed nothing
    assert pool.in_use == 2 and pool.available == 5
    pool.free(a)
    assert pool.available == pool.total
    with pytest.raises(ValueError, match="double free|not allocated"):
        pool.free([a[0]])
    assert pool.available == pool.total     # free list uncorrupted
    b = pool.alloc(pool.total)              # every id usable exactly once
    assert len(set(b)) == pool.total and 0 not in b


def test_pool_free_shim_warns_on_shared_block():
    pool = BlockPool(8)
    a = pool.alloc(1)
    pool.share(a)
    with pytest.warns(DeprecationWarning, match="shared block"):
        pool.free(a)
    assert pool.refcount(a[0]) == 1     # decremented, not fully freed


# --------------------------------------------------------------- radix index
def _toks(rng, n):
    return rng.integers(0, 97, n).astype(np.int32)


def test_radix_probe_match_insert_roundtrip():
    pool = BlockPool(32)
    idx = RadixPrefixIndex(pool, P)
    rng = np.random.default_rng(0)
    toks = _toks(rng, 4 * P + 5)            # 4 full blocks + ragged tail
    ids = pool.alloc(5)
    assert idx.insert(toks, ids) == 4       # full blocks only
    assert all(pool.refcount(i) == 2 for i in ids[:4])
    assert pool.refcount(ids[4]) == 1       # ragged tail never indexed
    assert idx.probe(toks) == 4 * P
    assert idx.probe(toks, max_tokens=len(toks) - 1) == 4 * P
    assert idx.probe(toks[: 2 * P + 3]) == 2 * P
    assert idx.match(toks[: 3 * P]) == ids[:3]
    assert idx.insert(toks, ids) == 0       # dedup: keys are the content
    # divergence after 2 blocks matches exactly those 2
    div = np.concatenate([toks[: 2 * P], _toks(rng, 2 * P) + 97])
    assert idx.probe(div % 97 + 0) <= 2 * P
    pool.release(ids)                       # owner gone; index retains
    assert idx.reclaimable() == 4
    assert idx.probe(toks) == 4 * P


def test_radix_evict_lru_leaves_first_and_skips_shared():
    pool = BlockPool(32)
    idx = RadixPrefixIndex(pool, P)
    rng = np.random.default_rng(1)
    t1, t2 = _toks(rng, 2 * P), _toks(rng, 2 * P)
    a, b = pool.alloc(2), pool.alloc(2)
    idx.insert(t1, a)
    idx.insert(t2, b)
    pool.release(a)
    pool.release(b)                         # both chains index-only
    idx.match(t1)                           # touch chain 1: chain 2 is LRU
    assert idx.evict(1) == 1
    assert idx.probe(t2) == P               # chain 2 lost its *leaf* only
    assert idx.probe(t1) == 2 * P
    # a chain some request still aliases is never evicted
    held = idx.match(t2)                    # [b[0]]
    pool.share(held)
    assert idx.evict(10) == 2               # only t1's chain + t2's root
    assert idx.probe(t2) == P and len(idx) == 1
    pool.release(held)
    assert idx.evict(10) == 1
    assert pool.available == pool.total and len(idx) == 0


def test_radix_lifecycle_property_never_leaks():
    """Randomized admit -> share -> finish/preempt -> evict churn: the
    pool never leaks or double-frees (available + in_use == total after
    every op), shared pages survive any single owner's exit, and a full
    drain (release actives + clear index) restores the empty pool."""
    rng = np.random.default_rng(7)
    pool = BlockPool(64)
    idx = RadixPrefixIndex(pool, P)
    families = [_toks(rng, 6 * P) for _ in range(3)]
    active = []                             # (tokens, blocks)

    def invariant():
        assert pool.available + pool.in_use == pool.total
        for _, blocks in active:
            assert all(pool.refcount(i) >= 1 for i in blocks)

    for _ in range(300):
        op = rng.integers(0, 4)
        if op == 0 and len(active) < 6:     # admit, aliasing what's cached
            fam = families[rng.integers(0, len(families))]
            n = int(rng.integers(P, 6 * P))
            toks = np.concatenate([fam[:n], _toks(rng, 5)])
            need = -(-len(toks) // P) + 1
            matched = idx.match(toks, max_tokens=len(toks) - 1)
            pool.share(matched)
            short = (need - len(matched)) - pool.available
            if short > 0:
                idx.evict(short)
            if need - len(matched) > pool.available:
                pool.release(matched)       # refused: full rollback
            else:
                active.append((toks, matched + pool.alloc(
                    need - len(matched))))
        elif op == 1 and active:            # finish: publish then release
            toks, blocks = active.pop(rng.integers(0, len(active)))
            idx.insert(toks, blocks)
            pool.release(blocks)
        elif op == 2 and active:            # preempt: release only
            _, blocks = active.pop(rng.integers(0, len(active)))
            pool.release(blocks)
        else:
            idx.evict(int(rng.integers(0, 3)))
        invariant()
    for _, blocks in active:
        pool.release(blocks)
    idx.clear()
    assert pool.available == pool.total and pool.in_use == 0


# ------------------------------------------------------------- model level
def test_aliased_prefix_logits_match_dense(params):
    """A suffix prefill over aliased prefix pages (pos preset to the
    cached span, padded rows routed to the null page) produces the same
    last-token logits as a dense full-prompt forward."""
    msl = 128
    npg = paged_slot_len(CFG, msl, P) // P
    paged = init_paged_cache(CFG, 2, msl, 1 + 2 * npg, P)
    rng = np.random.default_rng(3)
    a = _toks(rng, 57)                      # 3 full blocks + 9 tail
    b = np.concatenate([a[:48], _toks(rng, 9)])
    rowA = np.zeros(npg, np.int32)
    rowA[:4] = np.arange(1, 5)
    rowB = np.zeros(npg, np.int32)
    rowB[:3] = np.arange(1, 4)              # alias A's prefix pages
    rowB[3] = 5                             # fresh page for the suffix
    paged["block_tables"] = jnp.asarray(np.stack([rowA, rowB]))
    _, paged = forward_prefill_paged(params, CFG, tokens=jnp.asarray(a[None]),
                                     cache=paged, slot=0, length=57)
    paged["pos"] = paged["pos"].at[1].set(48)
    suf = np.zeros((1, 16), np.int32)       # padded beyond the 9 real rows
    suf[0, :9] = b[48:]
    got, paged = forward_chunk_paged(params, CFG, tokens=jnp.asarray(suf),
                                     cache=paged, slot=1, length=9)
    dense = init_cache(CFG, 1, msl)
    want, _, _ = forward_full(params, CFG, tokens=jnp.asarray(b[None]),
                              cache=dense)
    np.testing.assert_allclose(np.asarray(got[0, 0]),
                               np.asarray(want[0, len(b) - 1]),
                               atol=1e-5, rtol=1e-5)
    assert int(paged["pos"][1]) == 57


def test_copy_page_copies_every_attention_layer(params):
    cache = init_paged_cache(CFG, 1, 128, 8, P)
    k0 = cache["layers"][0]["k"]
    cache["layers"][0]["k"] = k0.at[2].set(1.5)
    cache = copy_page(cache, 2, 5)
    for layer in cache["layers"]:
        for v in layer.values():
            np.testing.assert_array_equal(np.asarray(v[5]),
                                          np.asarray(v[2]))


# ------------------------------------------------------------ engine level
class _Rec:
    """Profiler stand-in recording prefill token counts."""

    def __init__(self):
        self.prefill = []

    def observe_prefill(self, b, l, t):
        self.prefill.append(int(l))

    def observe_decode(self, b, l, t):
        pass


def test_engine_zero_prefill_flops_for_shared_span(params):
    """The headline reuse claim: a request whose prefix is cached
    prefills only its unique suffix (observed prefill work == suffix
    length), and still generates token-identical output vs an unshared
    engine."""
    rng = np.random.default_rng(4)
    shared = _toks(rng, 48)
    p0 = np.concatenate([shared, _toks(rng, 9)])
    p1 = np.concatenate([shared, _toks(rng, 13)])
    rec = _Rec()
    eng = Engine(CFG, params, max_slots=4, max_seq_len=256,
                 temperature=0.0, profiler=rec)
    out = eng.run_fcfs([_rt(p0, 0), _rt(p1, 1)])
    assert rec.prefill == [len(p0), len(p1) - 48]
    assert out[0]["cached"] == 0 and out[1]["cached"] == 48
    ref = Engine(CFG, params, max_slots=4, max_seq_len=256,
                 temperature=0.0, prefix_cache=False).run_fcfs(
        [_rt(p0, 0), _rt(p1, 1)])
    for k in out:
        assert out[k]["tokens"] == ref[k]["tokens"]
    assert eng.prefix_stats()["hit_rate"] > 0


def test_engine_multiturn_second_turn_hits_cache(params):
    """A turn-2 prompt extending a finished conversation aliases the
    pages the index retained at finish (prompt + generated tokens)."""
    rng = np.random.default_rng(5)
    p0 = _toks(rng, 57)
    eng = Engine(CFG, params, max_slots=2, max_seq_len=256,
                 temperature=0.0)
    out = eng.run_fcfs([_rt(p0, 0, max_new=4)])
    turn2 = np.concatenate([p0, np.asarray(out[0]["tokens"][:-1], np.int32),
                            _toks(rng, 7)])
    out2 = eng.run_fcfs([_rt(turn2, 1, max_new=4)])
    # 57 prompt + 3 written generated = 60 -> 3 full blocks cached
    assert out2[1]["cached"] == 48
    ref = Engine(CFG, params, max_slots=2, max_seq_len=256,
                 temperature=0.0, prefix_cache=False).run_fcfs(
        [_rt(turn2, 1, max_new=4)])
    assert out2[1]["tokens"] == ref[1]["tokens"]


def test_engine_shared_pages_survive_sharers_eviction(params):
    """Preempting a request that aliases cached pages releases only its
    reference: the survivor and the index keep the pages, and the
    preempted request re-matches them on re-admission."""
    rng = np.random.default_rng(6)
    shared = _toks(rng, 48)
    a = _rt(np.concatenate([shared, _toks(rng, 5)]), 0, max_new=8)
    b = _rt(np.concatenate([shared, _toks(rng, 7)]), 1, max_new=8)
    eng = Engine(CFG, params, max_slots=2, max_seq_len=256,
                 temperature=0.0)
    eng.prefill(a, 0)
    eng.prefill(b, 1)
    assert b.cached_tokens == 48
    shared_ids = eng._slot_blocks[1][:3]
    assert shared_ids == eng._slot_blocks[0][:3]
    assert all(eng.pool.refcount(i) == 3 for i in shared_ids)  # a, b, index
    eng.preempt(b)
    assert all(eng.pool.refcount(i) == 2 for i in shared_ids)  # a, index
    assert eng.prefix.probe(shared) == 48   # still cached
    eng.prefill(b, 1)                       # re-admit: matches again
    assert b.cached_tokens >= 48
    while a.phase.name != "FINISHED" or b.phase.name != "FINISHED":
        eng.decode_round()
    # only the index owns the cached pages now; accounting is exact
    assert eng.pool.available + eng.pool.in_use == eng.pool.total
    assert eng.pool.in_use == len(eng.prefix)
    eng.prefix.clear()
    assert eng.pool.available == eng.pool.total


def test_engine_prefix_admits_strictly_more_at_equal_hbm(params):
    """Acceptance: at the same block budget, prefix sharing runs
    strictly more requests concurrently than the PR-5 exclusive pool on
    a shared-prompt mix (5 blocks/request exclusive vs 2 unique)."""
    rng = np.random.default_rng(8)
    shared = _toks(rng, 48)
    prompts = [np.concatenate([shared, _toks(rng, 9)]) for _ in range(4)]

    def peak(prefix_cache):
        eng = Engine(CFG, params, max_slots=8, max_seq_len=256,
                     temperature=0.0, num_blocks=15,
                     prefix_cache=prefix_cache)
        seen = []
        orig = eng.decode_round

        def counting():
            seen.append(sum(not f for f in eng.slot_free))
            orig()
        eng.decode_round = counting
        out = eng.run_fcfs([_rt(p, i, max_new=8)
                            for i, p in enumerate(prompts)])
        assert all(len(v["tokens"]) == 8 for v in out.values())
        return max(seen)

    assert peak(True) > peak(False)


def test_engine_cow_splits_shared_frontier_block(params):
    """Copy-on-write guard: if a slot's write frontier lands in a page
    another owner shares (manufactured here — block-aligned matching
    makes it unreachable through admission), the page is split before
    the decode write and the phantom owner's refcount survives."""
    rng = np.random.default_rng(9)
    rt = _rt(_toks(rng, 20), 0, max_new=8)
    eng = Engine(CFG, params, max_slots=2, max_seq_len=256,
                 temperature=0.0)
    eng.prefill(rt, 0)
    bi = rt.input_len // P                  # frontier block
    old = eng._slot_blocks[0][bi]
    eng.pool.share([old])                   # phantom co-owner
    eng.decode_round()
    assert eng.cow_copies == 1
    new = eng._slot_blocks[0][bi]
    assert new != old
    assert eng.pool.refcount(old) == 1      # phantom keeps its page
    assert eng.pool.refcount(new) == 1
    assert int(eng.cache["block_tables"][0, bi]) == new
    eng.pool.release([old])
    assert eng.pool.available + eng.pool.in_use == eng.pool.total


def test_chunked_prefill_skips_cached_span(params):
    """The chunked discipline starts its chunk walk mid-sequence at the
    cached boundary and stays token-identical."""
    rng = np.random.default_rng(10)
    shared = _toks(rng, 48)
    prompts = [np.concatenate([shared, _toks(rng, 9 + i)])
               for i in range(2)]
    rec = _Rec()
    eng = Engine(CFG, params, max_slots=4, max_seq_len=256,
                 temperature=0.0, chunked_prefill=16, profiler=rec)
    # serialized runs: the second prompt claims its pages after the
    # first is indexed, so its chunk walk starts at the cached boundary
    # (prefills staged in the *same* tick advance in parallel under
    # chunk-as-tick and can only alias spans indexed when they start)
    out = dict(eng.run_fcfs([_rt(prompts[0], 0)]))
    out.update(eng.run_fcfs([_rt(prompts[1], 1)]))
    # request 1 prefilled only its 10-token unique suffix, in one chunk
    assert sum(rec.prefill) == len(prompts[0]) + (len(prompts[1]) - 48)
    ref_eng = Engine(CFG, params, max_slots=4, max_seq_len=256,
                     temperature=0.0, chunked_prefill=16,
                     prefix_cache=False)
    ref = dict(ref_eng.run_fcfs([_rt(prompts[0], 0)]))
    ref.update(ref_eng.run_fcfs([_rt(prompts[1], 1)]))
    for k in out:
        assert out[k]["tokens"] == ref[k]["tokens"]


# --------------------------------------------------------------- pricing
def test_annealer_backends_price_cached_prefix_identically():
    """numpy calculate_g and the jitted _eval_g agree (<= 1e-6 under
    x64) on a multi-turn workload with nonzero cached_prefix, and both
    actually discount: zeroing the cached column changes G."""
    reqs = sample_multiturn_requests(4, turns=3, seed=11)
    for r in reqs:
        r.predicted_output_len = r.output_len
        r.slo = dataclasses.replace(r.slo, ttft=0.2, tpot=0.02)
    arrays = as_arrays(reqs)
    assert arrays["cached_prefix"].max() > 0
    n = len(reqs)
    perm, bid = fcfs_schedule(n, 4)
    g_np = calculate_g(arrays, PAPER_TABLE2, perm, bid)
    bnd = np.zeros(n, np.int32)
    bnd[np.searchsorted(bid, np.unique(bid))] = 1
    with enable_x64():
        reqc = aj._pack(arrays, PAPER_TABLE2, n)
        g_jax, _ = aj._eval_g(reqc, jnp.asarray(perm, jnp.int32),
                              jnp.asarray(bnd, jnp.int32))
        assert abs(float(g_jax) - g_np) <= 1e-6 * max(abs(g_np), 1.0)
    flat = dict(arrays)
    flat["cached_prefix"] = np.zeros(n)
    g_flat = calculate_g(flat, PAPER_TABLE2, perm, bid)
    assert g_np != g_flat


def test_prefill_coefs_discounted_by_cached_prefix():
    """linear_request_coefs — the shared contract behind the numpy
    incremental evaluator AND the jax packer — prices prefill at the
    unique length only; decode terms keep the full context."""
    base = Request(req_id=0, task_type="chat", input_len=100,
                   output_len=20, slo=SLO(ttft=1.0, tpot=0.05))
    hit = dataclasses.replace(base, req_id=1, cached_prefix=64)
    coefs = linear_request_coefs(as_arrays([base, hit]), PAPER_TABLE2)
    assert coefs["pA"][1] < coefs["pA"][0]      # cheaper prefill
    assert coefs["pC"][1] < coefs["pC"][0]
    assert coefs["eA"][1] < coefs["eA"][0]      # exec inherits it
    assert coefs["tA"][1] == coefs["tA"][0]     # decode: full context
    assert coefs["tC"][1] == coefs["tC"][0]
    m = PAPER_TABLE2
    assert m.exec_time(1, 100, 20, cached=64) < m.exec_time(1, 100, 20)
    assert m.ttft_exec(1, 100, cached=64) < m.ttft_exec(1, 100)


# -------------------------------------------------------------- workloads
def test_multiturn_request_generator_shapes():
    reqs = sample_multiturn_requests(3, turns=3, seed=0, block_size=16)
    assert len(reqs) == 9
    times = [r.arrival_time for r in reqs]
    assert times == sorted(times)
    assert [r.req_id for r in reqs] == list(range(9))
    assert any(r.cached_prefix > 0 for r in reqs)
    for r in reqs:
        assert 0 <= r.cached_prefix < r.input_len
        assert r.cached_prefix % 16 == 0


def test_multiturn_token_generator_shares_prefixes():
    out = sample_multiturn_token_requests(4, turns=2, vocab=97, seed=0,
                                          system_prompt_len=48,
                                          n_system_prompts=2)
    assert len(out) == 8
    by_id = {r.req_id: (r, t) for r, t in out}
    assert sorted(by_id) == list(range(8))
    sys_heads = {tuple(t[:48]) for _, t in out}
    assert len(sys_heads) == 2              # two shared system prompts
    for r, t in out:
        assert r.input_len == len(t)
    # within a conversation, turn 2's prompt extends turn 1's: every
    # turn-1 prompt (4 conversations) is a strict prefix of another
    prompts = [t for _, t in out]
    extended = sum(
        1 for t in prompts
        if any(len(s) > len(t) and np.array_equal(s[:len(t)], t)
               for s in prompts))
    assert extended >= 4
