"""Multi-virtual-device correctness (subprocess: device count is locked at
first jax init, so these run in a child with 8 host devices)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_ep_moe_and_seq_parallel_attention_multidevice():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"))
    out = subprocess.run(
        [sys.executable, os.path.join(root, "tests", "helpers",
                                      "verify_multidevice.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "ALL OK" in out.stdout
