"""Sharded-serving correctness on a forced 8-device CPU host (run as a
subprocess: the device count is locked at first jax init).

Covers the tentpole acceptance bar end to end:
  1. raw sharded-vs-single logits parity (whole prefill, chunked
     prefill, decode) at <= 1e-5;
  2. the page arrays are *actually* head-sharded (per-device shard is
     1/tp of the kv-head axis) while block tables stay replicated;
  3. engine-level greedy token parity (stall + chunked disciplines),
     with the BlockPool invariants holding throughout and the pool
     draining clean — admission/prefix/CoW never see the mesh;
  4. fleet (N=2 tensor-parallel engines) token parity vs one engine on
     the same seeded trace.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.slo import SLO, Request  # noqa: E402
from repro.distributed.sharding import (ParallelismConfig, cache_specs,  # noqa: E402
                                        named, param_specs)
from repro.engine.engine import Engine  # noqa: E402
from repro.engine.request import RuntimeRequest  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.models import ModelConfig, init_params  # noqa: E402
from repro.models.cache import init_paged_cache  # noqa: E402
from repro.models.model import (forward_chunk_paged, forward_decode_paged,  # noqa: E402
                                forward_prefill_paged)

assert jax.local_device_count() == 8, jax.local_device_count()

CFG = ModelConfig(name="verify-tp", family="dense", num_layers=2,
                  d_model=64, num_heads=8, num_kv_heads=8, head_dim=8,
                  d_ff=128, vocab_size=97, dtype="float32")
PARAMS = init_params(jax.random.PRNGKey(0), CFG)
MESH = make_host_mesh()
assert dict(MESH.shape) == {"data": 1, "model": 8}, MESH.shape


def mk_requests(n=6, seed=0, out=8, shared_prefix=0):
    rng = np.random.default_rng(seed)
    pre = rng.integers(1, 96, shared_prefix).tolist() if shared_prefix \
        else []
    rts = []
    for i in range(n):
        toks = pre + rng.integers(1, 96,
                                  int(rng.integers(6, 40))).tolist()
        rts.append(RuntimeRequest(
            request=Request(req_id=i, task_type="chat",
                            input_len=len(toks), slo=SLO(),
                            output_len=out, arrival_time=0.0),
            prompt_tokens=np.asarray(toks, np.int32),
            max_new_tokens=out))
    return rts


# ------------------------------------------------- 1. raw logits parity
def check_logits_parity():
    par = ParallelismConfig(fsdp=False)
    sharded_params = jax.device_put(
        PARAMS, named(MESH, param_specs(PARAMS, CFG, MESH, par)))

    def fresh(shard):
        cache = init_paged_cache(CFG, 4, 128, 33, 16)
        bt = np.zeros((4, 8), np.int32)
        bt[0, :4] = [1, 2, 3, 4]
        cache["block_tables"] = jnp.asarray(bt)
        if not shard:
            return cache, None
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache)
        cs = named(MESH, cache_specs(shapes, CFG, MESH, par, 4))
        return jax.device_put(cache, cs), cs

    toks = jnp.asarray(np.arange(1, 25, dtype=np.int32)[None])
    c1, _ = fresh(False)
    c8, cs = fresh(True)
    repl = NamedSharding(MESH, P())

    lg1, c1 = jax.jit(forward_prefill_paged, static_argnums=(1,))(
        PARAMS, CFG, tokens=toks, cache=c1, slot=0, length=24)
    lg8, c8 = jax.jit(forward_prefill_paged, static_argnums=(1,),
                      out_shardings=(repl, cs))(
        sharded_params, CFG, tokens=toks, cache=c8, slot=0, length=24)
    d = float(np.max(np.abs(np.asarray(lg1) - np.asarray(lg8))))
    assert d <= 1e-5, f"prefill logits diff {d}"

    # the page arrays must be genuinely head-sharded: each device holds
    # 1/8 of the kv heads; the block tables stay fully replicated
    k = c8["layers"][0]["k"]
    shard_shape = k.addressable_shards[0].data.shape
    assert shard_shape[2] * 8 == k.shape[2], (shard_shape, k.shape)
    bt8 = c8["block_tables"]
    assert bt8.addressable_shards[0].data.shape == bt8.shape

    # chunked continuation parity (32-token prompt in two 16 chunks on
    # slot 1 — fresh pages)
    for cache, p, sh in ((c1, PARAMS, None), (c8, sharded_params, cs)):
        bt = np.array(cache["block_tables"])
        bt[1, :4] = [5, 6, 7, 8]
        cache["block_tables"] = jnp.asarray(bt) if sh is None else \
            jax.device_put(jnp.asarray(bt), NamedSharding(MESH, P()))
        cache["pos"] = cache["pos"].at[1].set(0)
    ctx = np.arange(30, 62, dtype=np.int32)
    outs = []
    for cache, p, sh in ((c1, PARAMS, None), (c8, sharded_params, cs)):
        kw = {} if sh is None else {"out_shardings": (repl, sh)}
        fn = jax.jit(forward_chunk_paged, static_argnums=(1,), **kw)
        _, cache = fn(p, CFG, tokens=jnp.asarray(ctx[None, :16]),
                      cache=cache, slot=1, length=16)
        lg, cache = fn(p, CFG, tokens=jnp.asarray(ctx[None, 16:]),
                       cache=cache, slot=1, length=16)
        outs.append((np.asarray(lg), cache))
    d = float(np.max(np.abs(outs[0][0] - outs[1][0])))
    assert d <= 1e-5, f"chunked prefill logits diff {d}"
    c1, c8 = outs[0][1], outs[1][1]

    # decode parity over both occupied slots
    t2 = jnp.asarray(np.array([[24], [61], [0], [0]], np.int32))
    lg1d, _ = jax.jit(forward_decode_paged, static_argnums=(1,))(
        PARAMS, CFG, tokens=t2, cache=c1)
    lg8d, _ = jax.jit(forward_decode_paged, static_argnums=(1,),
                      out_shardings=(repl, cs))(
        sharded_params, CFG, tokens=t2, cache=c8)
    d = float(np.max(np.abs(np.asarray(lg1d) - np.asarray(lg8d))))
    assert d <= 1e-5, f"decode logits diff {d}"
    print(f"logits parity OK (prefill/chunk/decode <= 1e-5)")


# --------------------------------------- 2. engine parity + pool invariants
def pool_ok(eng):
    return eng.pool.available + eng.pool.in_use == eng.pool.total


def check_engine_parity():
    for disc, chunk in (("stall", 0), ("chunked", 16)):
        ref = Engine(CFG, PARAMS, max_slots=4, max_seq_len=128,
                     chunked_prefill=chunk)
        tp = Engine(CFG, PARAMS, max_slots=4, max_seq_len=128,
                    chunked_prefill=chunk, mesh=MESH)
        assert tp.cache["layers"][0]["k"].addressable_shards[0] \
            .data.shape[2] * 8 == CFG.num_kv_heads
        # shared prefix exercises aliasing + CoW under the mesh
        r_ref = ref.run_fcfs(mk_requests(seed=3, shared_prefix=24))
        assert pool_ok(tp)
        r_tp = tp.run_fcfs(mk_requests(seed=3, shared_prefix=24))
        assert pool_ok(tp)
        for k in r_ref:
            assert r_ref[k]["tokens"] == r_tp[k]["tokens"], \
                (disc, k, r_ref[k]["tokens"], r_tp[k]["tokens"])
            assert r_ref[k]["cached"] == r_tp[k]["cached"]
        # drained: every slot free, only prefix-index refs remain
        assert all(tp.slot_free)
        assert tp.pool.in_use == (len(tp.prefix) if tp.prefix else 0)
        print(f"engine token parity OK ({disc}, "
              f"cached={sum(r_tp[k]['cached'] for k in r_tp)}, "
              f"cow={tp.cow_copies})")


def check_cow_under_mesh():
    """Copy-on-write splits a shared frontier page while the cache is
    mesh-sharded: the split (host-side copy_page) must re-commit the
    tree to its shardings and decode identically to the unsharded
    engine.  Manufactured via ``pool.share`` — block-aligned prefix
    matching makes the case unreachable through admission."""
    rng = np.random.default_rng(9)
    toks = rng.integers(1, 96, 20).astype(np.int32)

    def split(mesh):
        eng = Engine(CFG, PARAMS, max_slots=2, max_seq_len=128,
                     mesh=mesh)
        rt = mk_requests(n=1, seed=11)[0]
        rt.prompt_tokens = toks
        rt.request = Request(req_id=0, task_type="chat", input_len=20,
                             slo=SLO(), output_len=4)
        eng.prefill(rt, 0)
        bi = 20 // eng.block_size
        eng.pool.share([eng._slot_blocks[0][bi]])
        eng.decode_round()
        assert eng.cow_copies == 1
        return rt.generated, eng

    g_ref, _ = split(None)
    g_tp, eng = split(MESH)
    assert g_ref == g_tp, (g_ref, g_tp)
    k = eng.cache["layers"][0]["k"]
    assert k.addressable_shards[0].data.shape[2] * 8 == k.shape[2]
    print("copy-on-write page split OK under mesh sharding")


# ----------------------------------------------------- 3. fleet parity
def check_fleet_parity():
    from repro.serving import EngineFleet, ServeLoop
    wl = [(rt.request, rt.prompt_tokens)
          for rt in mk_requests(n=8, seed=5, out=6)]
    single = ServeLoop(Engine(CFG, PARAMS, max_slots=4, max_seq_len=128))
    s_streams = single.submit_trace(
        [(r, t) for r, t in [(rt.request, rt.prompt_tokens)
                             for rt in mk_requests(n=8, seed=5, out=6)]])
    single.serve()
    fleet = EngineFleet(
        [Engine(CFG, PARAMS, max_slots=4, max_seq_len=128, mesh=MESH)
         for _ in range(2)], mapper="least-loaded")
    f_streams = fleet.submit_trace(wl)
    fleet.serve()
    for i, (ss, fs) in enumerate(zip(s_streams, f_streams)):
        assert ss.tokens == fs.tokens, (i, ss.tokens, fs.tokens)
    m = fleet.metrics.summary()
    assert m["n"] == 8
    print(f"fleet (2x tp8 engines) token parity OK, "
          f"tokens={m['tokens']}")


if __name__ == "__main__":
    check_logits_parity()
    check_engine_parity()
    check_cow_under_mesh()
    check_fleet_parity()
    print("ALL OK")
