import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.models.moe import ShardingCtx, init_moe, moe_ffn, _local_moe
from repro.models.config import ModelConfig, MoEConfig

cfg = ModelConfig(name="m", family="moe", num_layers=1, d_model=64,
                  num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=97, dtype="float32",
                  moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=96))
params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 64), jnp.float32)
out_ref, aux_ref = moe_ffn(params, cfg, x, None)
mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = ShardingCtx(mesh=mesh, dp_axes=("data",), tp_axis="model", expert_parallel=True)
out_ep, aux_ep = jax.jit(lambda p, xx: moe_ffn(p, cfg, xx, ctx))(params, x)
err = float(jnp.max(jnp.abs(out_ep - out_ref)))
print("EP vs local max err:", err, "aux", float(aux_ep), float(aux_ref))
assert err < 1e-4
# gather-baseline path too
ctx2 = ShardingCtx(mesh=mesh, dp_axes=("data",), tp_axis="model", expert_parallel=False)
out_g, _ = jax.jit(lambda p, xx: moe_ffn(p, cfg, xx, ctx2))(params, x)
err2 = float(jnp.max(jnp.abs(out_g - out_ref)))
print("gather vs local max err:", err2)
assert err2 < 1e-4

# 2D expert-parallel (decode-style small token count, fsdp ff sharding)
ctx4 = ShardingCtx(mesh=mesh, dp_axes=("data",), tp_axis="model",
                   expert_parallel=True, fsdp_axes=("data",))
out_2d, _ = jax.jit(lambda p, xx: moe_ffn(p, cfg, xx, ctx4))(params, x)
err4 = float(jnp.max(jnp.abs(out_2d - out_ref)))
print("EP-2D vs local max err:", err4)
assert err4 < 1e-4
# seq-parallel attention correctness on a multi-device mesh
from repro.models import init_params, forward_full
from repro.models.config import ModelConfig as MC
dcfg = MC(name="d", family="dense", num_layers=2, d_model=64, num_heads=6,
          num_kv_heads=2, d_ff=128, vocab_size=97, dtype="float32")
dp = init_params(jax.random.PRNGKey(2), dcfg)
toks = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, 97)
ref, _, _ = forward_full(dp, dcfg, tokens=toks)
ctx3 = ShardingCtx(mesh=mesh, dp_axes=("data",), tp_axis="model", attn_sharding="auto")
got, _, _ = jax.jit(lambda p, t: forward_full(p, dcfg, tokens=t, ctx=ctx3)[:2])(dp, toks)[0], None, None
err3 = float(jnp.max(jnp.abs(got - ref)))
print("seq-par attn vs local max err:", err3)
assert err3 < 1e-3
print("ALL OK")
