"""Paged KV pool: kernel parity, paged-vs-dense decode parity (logits
<= 1e-5 over mixed lengths, incl. quantized KV and GQA), block alloc/free
invariants across admit -> preempt -> re-admit -> finish, and
out-of-blocks admission refusal."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.slo import SLO, Request
from repro.engine.blocks import BlockPool
from repro.engine.engine import Engine
from repro.engine.request import RuntimeRequest
from repro.kernels import ref
from repro.kernels.decode_attention_paged import (decode_attention_paged,
                                                  decode_attention_paged_q8)
from repro.models import ModelConfig, init_cache, init_params
from repro.models.cache import (init_paged_cache, paged_slot_len,
                                quantize_kv)
from repro.models.model import (forward_decode, forward_decode_paged,
                                forward_full, forward_prefill_paged)

CFG = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                  dtype="float32")


def _rts(n, seed=0, vocab=97, max_new=4, lo=8, hi=40):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        ln = int(rng.integers(lo, hi))
        out.append(RuntimeRequest(
            request=Request(req_id=i, task_type="chat", input_len=ln,
                            slo=SLO(ttft=100.0, tpot=10.0)),
            prompt_tokens=rng.integers(0, vocab, ln).astype(np.int32),
            max_new_tokens=max_new))
    return out


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


# ------------------------------------------------------------ kernel level
@pytest.mark.parametrize("b,h,kv,hd,P,npg,window", [
    (1, 4, 4, 32, 16, 4, 0),       # MHA
    (3, 8, 2, 64, 16, 8, 0),       # GQA 4x
    (2, 4, 1, 64, 32, 4, 0),       # MQA
    (2, 8, 2, 64, 16, 4, 24),      # sliding window over a rounded ring
])
def test_paged_kernel_matches_ref(b, h, kv, hd, P, npg, window):
    """Pallas paged flash-decode (interpret) vs the gather oracle, over
    mixed lengths including ring wrap (lengths > ring)."""
    L = P * npg
    nb = 1 + b * npg
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (nb, P, kv, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (nb, P, kv, hd), jnp.float32)
    bt = jnp.arange(1, 1 + b * npg, dtype=jnp.int32).reshape(b, npg)
    lengths = jnp.asarray(
        np.linspace(3, L + P, b).astype(np.int32))     # incl. wrapped
    out = decode_attention_paged(q, kp, vp, bt, lengths, window=window,
                                 interpret=True)
    want = ref.decode_attention_paged_ref(q, kp, vp, bt, lengths,
                                          window=window)
    np.testing.assert_allclose(out, want, atol=1e-5, rtol=1e-5)


def test_paged_kernel_matches_dense_kernel_ref():
    """No wrap, full table: paged ref == dense decode ref on the gathered
    cache (the layouts describe the same logical cache)."""
    b, h, kv, hd, P, npg = 2, 8, 2, 64, 16, 8
    L = P * npg
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (1 + b * npg, P, kv, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (1 + b * npg, P, kv, hd), jnp.float32)
    bt = jnp.arange(1, 1 + b * npg, dtype=jnp.int32).reshape(b, npg)
    lengths = jnp.array([40, L], jnp.int32)
    kc = kp[bt].reshape(b, L, kv, hd)
    vc = vp[bt].reshape(b, L, kv, hd)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    got = ref.decode_attention_paged_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(got, want, atol=1e-6, rtol=1e-6)


def test_paged_kernel_q8_matches_ref():
    b, h, kv, hd, P, npg = 2, 8, 2, 64, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    kf = jax.random.normal(ks[1], (1 + b * npg, P, kv, hd), jnp.float32)
    vf = jax.random.normal(ks[2], (1 + b * npg, P, kv, hd), jnp.float32)
    kq, ksc = quantize_kv(kf)
    vq, vsc = quantize_kv(vf)
    bt = jnp.arange(1, 1 + b * npg, dtype=jnp.int32).reshape(b, npg)
    lengths = jnp.array([17, P * npg], jnp.int32)
    out = decode_attention_paged_q8(q, kq, ksc, vq, vsc, bt, lengths,
                                    interpret=True)
    from repro.models.cache import dequantize_kv
    want = ref.decode_attention_paged_ref(
        q, dequantize_kv(kq, ksc).astype(jnp.float32),
        dequantize_kv(vq, vsc).astype(jnp.float32), bt, lengths)
    np.testing.assert_allclose(out, want, atol=5e-3, rtol=5e-3)


# ------------------------------------------------------------- model level
def _identity_tables(B, npg):
    return jnp.arange(1, 1 + B * npg, dtype=jnp.int32).reshape(B, npg)


@pytest.mark.parametrize("quantized", [False, True])
def test_paged_decode_matches_dense_logits(params, quantized):
    """Prefill both layouts from the same prompts (mixed lengths), then
    several decode steps: logits agree to <= 1e-5 (GQA arch; exact for
    the unquantized full-attention layout)."""
    B, msl, P = 3, 128, 16
    lens = [9, 24, 57]
    rng = np.random.default_rng(0)
    dense = init_cache(CFG, B, msl, quantized=quantized)
    npg = paged_slot_len(CFG, msl, P) // P
    paged = init_paged_cache(CFG, B, msl, 1 + B * npg, P,
                             quantized=quantized)
    paged["block_tables"] = _identity_tables(B, npg)
    for s, n in enumerate(lens):
        toks = jnp.asarray(rng.integers(0, 97, (1, n)).astype(np.int32))
        d1 = init_cache(CFG, 1, msl, quantized=quantized)
        _, d1, _ = forward_full(params, CFG, tokens=toks, cache=d1)
        for li in range(CFG.num_layers):
            for k in dense["layers"][li]:
                dense["layers"][li][k] = \
                    dense["layers"][li][k].at[s].set(d1["layers"][li][k][0])
        dense["pos"] = dense["pos"].at[s].set(n)
        _, paged = forward_prefill_paged(params, CFG, tokens=toks,
                                         cache=paged, slot=s, length=n)
    nxt = jnp.asarray(rng.integers(0, 97, (B, 1)).astype(np.int32))
    for _ in range(3):
        gd, dense = forward_decode(params, CFG, tokens=nxt, cache=dense)
        gp, paged = forward_decode_paged(params, CFG, tokens=nxt,
                                         cache=paged)
        np.testing.assert_allclose(gp, gd, atol=1e-5, rtol=1e-5)
        nxt = jnp.argmax(gd[:, -1], -1)[:, None]


def test_paged_engine_matches_dense_engine(params):
    """End-to-end: the paged engine generates the same greedy tokens as
    the dense engine (full-attention arch: bit-identical attended sets)."""
    a = Engine(CFG, params, max_slots=3, max_seq_len=128).run_fcfs(
        _rts(5, seed=3))
    b = Engine(CFG, params, max_slots=3, max_seq_len=128,
               paged=False).run_fcfs(_rts(5, seed=3))
    assert all(a[i]["tokens"] == b[i]["tokens"] for i in a)


def test_paged_engine_chunked_matches_dense(params):
    """Chunked in-place prefill: same greedy tokens as the dense engine's
    whole-prompt path."""
    a = Engine(CFG, params, max_slots=3, max_seq_len=128,
               chunked_prefill=16).run_fcfs(_rts(5, seed=4))
    b = Engine(CFG, params, max_slots=3, max_seq_len=128,
               paged=False).run_fcfs(_rts(5, seed=4))
    assert all(a[i]["tokens"] == b[i]["tokens"] for i in a)


def test_paged_chunked_quantized_cache_roundtrip(params):
    """Chunked continuation on an int8 paged cache keeps the scale pages
    and dequantizes the prefix: decode after chunked prefill stays close
    to decode after whole-prompt prefill (quantization drift only)."""
    from repro.models.model import forward_chunk_paged
    P, msl = 16, 128
    npg = paged_slot_len(CFG, msl, P) // P
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 24), 0, 97)

    def fresh():
        c = init_paged_cache(CFG, 1, msl, 1 + npg, P, quantized=True)
        c["block_tables"] = _identity_tables(1, npg)
        return c
    a = fresh()
    _, a = forward_prefill_paged(params, CFG, tokens=toks, cache=a,
                                 slot=0, length=24)
    b = fresh()
    for i in range(0, 24, 8):
        _, b = forward_chunk_paged(params, CFG, tokens=toks[:, i:i + 8],
                                   cache=b, slot=0)
    assert "k_scale" in b["layers"][0] and "v_scale" in b["layers"][0]
    nxt = jnp.array([[5]])
    ga, _ = forward_decode_paged(params, CFG, tokens=nxt, cache=a)
    gb, _ = forward_decode_paged(params, CFG, tokens=nxt, cache=b)
    assert float(jnp.max(jnp.abs(ga - gb))) < 0.15


def test_preempt_policy_caps_block_need_at_ring(params):
    """Regression: pending_blocks is capped at the slot ring like the
    engine's own reservation — a windowed request whose prompt + output
    exceed the ring must still be admitted by SLOPreemptPolicy."""
    from repro.core.latency_model import PAPER_TABLE2
    cfg = ModelConfig(name="w", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                      dtype="float32", sliding_window=32)
    p = init_params(jax.random.PRNGKey(4), cfg)
    rng = np.random.default_rng(4)
    rt = RuntimeRequest(
        request=Request(req_id=0, task_type="chat", input_len=30,
                        slo=SLO(ttft=100.0, tpot=10.0), output_len=40),
        prompt_tokens=rng.integers(0, 97, 30).astype(np.int32),
        max_new_tokens=40)
    rt.request.predicted_output_len = 40
    eng = Engine(cfg, p, max_slots=1, max_seq_len=128, block_size=16)
    out = eng.run_policy([rt], "slo-preempt", model=PAPER_TABLE2)
    assert len(out[0]["tokens"]) == 40


def test_chunked_prefill_warms_and_profiles_chunks(params):
    """Regression: prefill_chunked must warm the chunk jit per chunk size
    (compile time off the engine clock) and feed every chunk timing to
    the profiler."""
    from repro.core.profiler import LatencyProfiler
    prof = LatencyProfiler()
    eng = Engine(CFG, params, max_slots=2, max_seq_len=128,
                 chunked_prefill=16, profiler=prof)
    rts = _rts(2, seed=5, lo=33, hi=40)     # >= 3 chunks each
    n_chunks = sum(-(-rt.input_len // 16) for rt in rts)
    eng.run_fcfs(rts)
    assert len(prof.prefill_samples) == n_chunks
    assert any(k[0] == "chunk" for k in eng._warm)
    # compile happened off the clock: chunk samples are msec-scale, not
    # the tens-of-msec a tiny-model jit compile costs
    assert max(t for _, _, t in prof.prefill_samples) < 1.0


# ----------------------------------------------------------- block pool
def test_block_pool_invariants():
    pool = BlockPool(8)
    assert pool.total == 7 and pool.available == 7
    a = pool.alloc(3)
    b = pool.alloc(4)
    assert pool.available == 0 and pool.in_use == 7
    assert 0 not in a + b                   # null page never handed out
    with pytest.raises(RuntimeError):
        pool.alloc(1)
    pool.free(a)
    assert pool.available == 3
    with pytest.raises(ValueError):
        pool.free(a)                        # double free
    pool.free(b)
    assert pool.available == 7 and pool.in_use == 0


def test_engine_blocks_across_admit_preempt_readmit_finish(params):
    """Alloc/free invariants over the full lifecycle: blocks are held
    exactly while a request holds a slot, re-admission re-allocates, and
    the pool drains back to full after every request finishes.  Runs
    with the prefix cache off: exclusive PR-5 ownership semantics (with
    prefix sharing, the index deliberately retains pages — covered in
    test_prefix.py)."""
    eng = Engine(CFG, params, max_slots=2, max_seq_len=128,
                 prefix_cache=False)
    total = eng.pool.total
    rt = _rts(1, seed=6)[0]
    eng.prefill(rt, 0)
    held = eng.pool.in_use
    assert held == eng._blocks_needed(rt) > 0
    assert np.asarray(eng.cache["block_tables"])[0].max() > 0
    eng.preempt(rt)
    assert eng.pool.in_use == 0 and eng.pool.available == total
    assert np.asarray(eng.cache["block_tables"])[0].max() == 0
    eng.prefill(rt, 1)                      # re-admit on another slot
    assert eng.pool.in_use == eng._blocks_needed(rt)
    while rt.phase.name != "FINISHED":
        eng.decode_round()
    assert eng.pool.in_use == 0 and eng.pool.available == total
    assert np.asarray(eng.cache["block_tables"]).max() == 0


def test_engine_out_of_blocks_admission_refusal(params):
    """A pool covering one request at a time: the second admission is
    refused until the first finishes — both still complete, sequentially."""
    rts = _rts(2, seed=7, lo=30, hi=36, max_new=4)
    need = -(-(36 + 4) // 16)
    eng = Engine(CFG, params, max_slots=2, max_seq_len=128,
                 num_blocks=need + 1,       # + null page: fits ONE request
                 prefix_cache=False)        # exclusive-pool drain semantics
    out = eng.run_fcfs(rts)
    assert all(len(v["tokens"]) == 4 for v in out.values())
    # sequential service: 1 could only start after 0 finished
    assert out[1]["ttft"] > out[0]["e2e"] * 0.5
    assert eng.pool.available == eng.pool.total


def test_engine_unservable_request_raises(params):
    """A request whose prompt + output budget exceeds the whole pool is
    refused permanently (ValueError, not a silent stall)."""
    rts = _rts(1, seed=8, lo=60, hi=61, max_new=4)
    eng = Engine(CFG, params, max_slots=2, max_seq_len=128, num_blocks=3)
    with pytest.raises(ValueError, match="KV blocks"):
        eng.run_fcfs(rts)


def test_paged_pool_admits_more_than_dense_at_equal_hbm(params):
    """The headline capacity claim: at the HBM budget of a 2-slot dense
    engine, the paged pool serves a short-prompt mix >= 2x more
    concurrently (slots are cheap; tokens are the budget)."""
    from repro.models.cache import kv_bytes_per_token
    msl = 128
    bpt = kv_bytes_per_token(CFG)
    hbm = 2 * msl * bpt                     # dense: 2 full-length slots
    block_size = 16
    num_blocks = hbm // (block_size * bpt)  # same HBM in pages
    # short-prompt mix: 24-token prompts + 8 output -> 2 blocks each
    rts = _rts(8, seed=9, lo=24, hi=25, max_new=8)
    eng = Engine(CFG, params, max_slots=8, max_seq_len=msl,
                 block_size=block_size, num_blocks=int(num_blocks) + 1)
    concurrent = []
    orig = eng.decode_round

    def counting_round():
        concurrent.append(sum(not f for f in eng.slot_free))
        orig()
    eng.decode_round = counting_round
    out = eng.run_fcfs(rts)
    assert all(len(v["tokens"]) == 8 for v in out.values())
    assert max(concurrent) >= 4             # dense admits 2 at this HBM


def test_scheduler_view_exposes_block_occupancy(params):
    """SchedulerView carries the pool occupancy while requests run."""
    from repro.core.policies import SchedulingPolicy, Decision

    class Probe(SchedulingPolicy):
        views = []

        def decide(self, view):
            Probe.views.append(view)
            return Decision(admit=list(range(min(view.free,
                                                 len(view.pending)))))
    Probe.views = []
    eng = Engine(CFG, params, max_slots=2, max_seq_len=128)
    rts = _rts(4, seed=10)
    for i, rt in enumerate(rts):            # staggered finishes: later
        rt.max_new_tokens = 3 + 3 * i       # views see running requests
    eng.run_policy(rts, Probe())
    assert all(v.total_blocks == eng.pool.total for v in Probe.views)
    assert all(v.block_size == 16 for v in Probe.views)
    busy = [v for v in Probe.views if v.active]
    assert busy, "no view saw active requests"
    assert any(v.free_blocks < v.total_blocks for v in busy)
    assert all(a.blocks_held > 0 for v in busy for a in v.active)
    v = busy[0]
    assert v.blocks_for(17) == 2 and v.pending_blocks(0) > 0


def test_preempt_policy_memory_aware_eviction():
    """On a block-starved view, SLOPreemptPolicy filters admissions to
    the free blocks and evicts the victim freeing the most blocks per
    slack to make a tight arrival fit."""
    from repro.core.latency_model import PAPER_TABLE2
    from repro.core.policies import (SLOPreemptPolicy, SchedulerView,
                                     make_active_view)
    tight = Request(req_id=0, task_type="chat", input_len=32,
                    slo=SLO(ttft=0.2), output_len=8)
    tight.predicted_output_len = 8
    tight.submit_time = 0.0
    victims = []
    for rid, (blocks, out_len) in enumerate([(2, 400), (12, 400)], start=1):
        r = Request(req_id=rid, task_type="code", input_len=16,
                    slo=SLO(e2e=1e4), output_len=out_len)
        r.submit_time = 0.0
        victims.append(make_active_view(
            r, generated=4, remaining=out_len - 4, context_len=20,
            now=0.0, ttft=0.0, e2e_base=0.0, batch=2, model=PAPER_TABLE2,
            blocks_held=blocks))
    view = SchedulerView(pending=(tight,), active=tuple(victims), now=0.0,
                         free=1, max_batch=4, pending_generated=(0,),
                         free_blocks=0, total_blocks=14, block_size=16)
    dec = SLOPreemptPolicy(PAPER_TABLE2).decide(view)
    # a free slot exists but zero free blocks: eviction must free the
    # big-holding victim (index 1), then the arrival is admitted
    assert dec.preempt == [1]
    assert dec.admit == [0]
