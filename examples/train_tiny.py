"""Train a small decoder end-to-end with the training substrate.

Trains a ~25M-parameter qwen3-family model (the reduced config scaled up)
for a few hundred steps on synthetic data, demonstrating the train_step /
AdamW / remat path that the ``train_4k`` dry-run shape exercises at scale.

Run:  PYTHONPATH=src python examples/train_tiny.py [--steps 200]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data.synthetic import token_stream
from repro.models import init_params
from repro.train import optimizer as opt
from repro.train.train_step import train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--arch", default="qwen3-1.7b")
    args = ap.parse_args()

    cfg = get_reduced(args.arch, num_layers=4, d_model=256, vocab_size=1024)
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params)
                   if hasattr(x, "size"))
    print(f"arch={cfg.name} params={n_params / 1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    ocfg = opt.AdamWConfig(lr=3e-4, warmup_steps=20)
    state = opt.init(params)
    step_fn = jax.jit(lambda p, s, b: train_step(cfg, ocfg, p, s, b))

    # synthetic corpus with learnable structure (shifted-window repeats)
    rng = np.random.default_rng(0)
    base = token_stream(args.seq * 64, cfg.vocab_size, seed=1)[0]

    losses = []
    t0 = time.time()
    for step in range(args.steps):
        starts = rng.integers(0, len(base) - args.seq - 1, args.batch)
        toks = np.stack([base[s:s + args.seq] for s in starts])
        labels = np.stack([base[s + 1:s + args.seq + 1] for s in starts])
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        params, state, metrics = step_fn(params, state, batch)
        losses.append(float(metrics["loss"]))
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                  f"grad_norm {float(metrics['grad_norm']):.3f}  "
                  f"({(time.time() - t0) / (step + 1):.2f}s/step)")
    assert losses[-1] < losses[0], "training did not reduce loss"
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} steps")


if __name__ == "__main__":
    main()
