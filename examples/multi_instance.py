"""Multi-instance SLO-aware scheduling (Algorithm 2, paper §4.4 / Fig. 11).

Requests are assigned round-robin to the instance with the largest
remaining memory (Eq. 20 token accounting), priority-mapped independently
per instance (embarrassingly parallel), and dispatched.

The planned schedule is also scored through the event core under both
execution disciplines (stalling vs Sarathi-style chunked prefill) before
dispatch — scheduling API v2.

Run:  PYTHONPATH=src python examples/multi_instance.py [--instances 4]
"""
import argparse
import time

from repro.core import (PAPER_TABLE2, SAParams, SLOAwareScheduler,
                        run_fcfs_continuous, run_priority_continuous)
from repro.core.profiler import MemoryModel
from repro.data.synthetic import sample_requests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--instances", type=int, default=4)
    ap.add_argument("--n", type=int, default=40)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    model = PAPER_TABLE2
    reqs = sample_requests(args.n, seed=5)
    for r in reqs:
        r.predicted_output_len = r.output_len      # S3-style oracle predictor

    # 32 GB per instance; ~200 kB KV per token at 7B fp16 (Eq. 20)
    memory = MemoryModel(total_memory=32e9, mu=0.9, sigma_per_token=2e5)
    sched = SLOAwareScheduler(model, num_instances=args.instances,
                              max_batch=args.max_batch, memory=memory,
                              sa_params=SAParams(seed=0,
                                                 budget_mode="per_level"))
    t0 = time.perf_counter()
    outcome = sched.schedule(reqs)
    dt = time.perf_counter() - t0

    met = tot = 0
    for q in outcome.queues:
        sim = run_priority_continuous(q.batches, model, args.max_batch)
        met += sum(sim.met.values())
        tot += sim.total_latency
        print(f"instance {q.instance_id}: {len(q)} requests, "
              f"{len(q.batches)} planned batches, "
              f"G={sim.G:.4f}, attainment={sim.attainment:.2f}")
    print(f"\noverall G={met / tot if tot else 0:.4f}  "
          f"scheduling overhead={dt * 1e3:.2f} ms "
          f"({args.instances} instances, sequential host)")

    # score the same plan under both execution disciplines (API v2)
    for disc in ("stall", "chunked:32"):
        ev = sched.evaluate_plan(outcome, discipline=disc)
        print(f"plan under {disc:<10}: G={ev.G:.4f} "
              f"attainment={ev.attainment:.2f}")

    # FCFS baseline with the same round-robin split
    met = tot = 0
    for i in range(args.instances):
        sim = run_fcfs_continuous(reqs[i::args.instances], model,
                                  args.max_batch)
        met += sum(sim.met.values())
        tot += sim.total_latency
    print(f"FCFS     G={met / tot if tot else 0:.4f}")


if __name__ == "__main__":
    main()
