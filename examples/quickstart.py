"""Quickstart: SLO-aware scheduling in five minutes.

1. Profile a real JAX engine to fit the latency model (Eqs. 14-15).
2. Build a mixed chat+code workload with distinct SLOs.
3. Schedule with the simulated-annealing priority mapper (Algorithm 1).
4. Execute BOTH plans on the engine and compare G / attainment / latency.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

from repro.core import (SAParams, SLOAwareScheduler, as_arrays, evaluate)
from repro.core.profiler import LatencyProfiler, OutputLengthPredictor
from repro.core.slo import SLO, Request
from repro.engine.engine import Engine
from repro.engine.request import RuntimeRequest
from repro.models import ModelConfig, init_params

VOCAB = 512
CFG = ModelConfig(name="quickstart-28m", family="dense", num_layers=4,
                  d_model=256, num_heads=8, num_kv_heads=4, d_ff=1024,
                  vocab_size=VOCAB, dtype="float32")


def make_workload(n, rng):
    """Half code-completion (e2e SLO), half chat (TTFT+TPOT SLO)."""
    rts = []
    for i in range(n):
        if i % 2 == 0:
            slo, task = SLO(e2e=6.0), "code"
            lin, lout = int(rng.integers(48, 96)), int(rng.integers(24, 48))
        else:
            slo, task = SLO(ttft=2.0, tpot=0.25), "chat"
            lin, lout = int(rng.integers(16, 64)), int(rng.integers(8, 24))
        rts.append(RuntimeRequest(
            request=Request(req_id=i, task_type=task, input_len=lin,
                            slo=slo, output_len=lout),
            prompt_tokens=rng.integers(0, VOCAB, lin).astype(np.int32),
            max_new_tokens=lout))
    return rts


def summarize(tag, out, reqs):
    met = sum(v["met"] for v in out.values())
    tot = sum(v["e2e"] for v in out.values())
    g = met / tot if tot else 0.0
    print(f"  {tag:12s} G={g:.4f} req/s   attainment={met}/{len(out)}   "
          f"avg latency={tot / len(out):.2f}s")
    return g


def main():
    rng = np.random.default_rng(0)
    params = init_params(jax.random.PRNGKey(0), CFG)

    # --- 1. profile the engine and fit the latency model
    print("profiling engine ...")
    prof = LatencyProfiler()
    Engine(CFG, params, max_slots=4, max_seq_len=256,
           profiler=prof).run_fcfs(make_workload(8, rng))
    model = prof.fit()
    print(f"  fitted: t_p(1,64)={model.prefill_time(1, 64) * 1e3:.1f}ms  "
          f"tau_d(4,128)={model.per_token_decode_time(4, 128) * 1e3:.2f}ms")

    # --- 2. workload
    rts = make_workload(10, rng)
    reqs = [rt.request for rt in rts]
    for rt, r in zip(rts, reqs):
        r.predicted_output_len = rt.max_new_tokens   # business-supplied hint

    # --- 3. schedule (Algorithm 1 + 2)
    sched = SLOAwareScheduler(model, num_instances=1, max_batch=4,
                              sa_params=SAParams(seed=0,
                                                 budget_mode="per_level"))
    outcome = sched.schedule(reqs)
    order = [r.req_id for b in outcome.queues[0].batches for r in b]
    print(f"SLO-aware priority order: {order}")
    print(f"predicted G = {outcome.predicted_G:.4f} req/s")

    # --- 4. execute both policies on the REAL engine
    print("executing FCFS on engine ...")
    eng = Engine(CFG, params, max_slots=4, max_seq_len=256)
    out_fcfs = eng.run_fcfs(rts)
    g0 = summarize("fcfs", out_fcfs, reqs)

    print("executing SLO-aware plan on engine ...")
    by_id = {rt.req_id: rt for rt in rts}
    planned = [[by_id[r.req_id] for r in batch]
               for batch in outcome.queues[0].batches]
    for rt in rts:     # reset runtime state
        rt.generated, rt.phase = [], rt.phase.__class__.WAITING
        rt.ttft_time = rt.finish_time = None
    eng2 = Engine(CFG, params, max_slots=4, max_seq_len=256)
    out_slo = eng2.run_planned(planned)
    g1 = summarize("slo-aware", out_slo, reqs)
    if g0 > 0:
        print(f"G improvement: {100 * (g1 - g0) / g0:+.1f}%")
    else:
        print(f"G improvement: fcfs attained 0 SLOs; slo-aware G={g1:.4f}")


if __name__ == "__main__":
    main()
