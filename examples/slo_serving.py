"""End-to-end SLO-aware serving driver (the paper's full pipeline).

Stages, exactly as §5.1 "Workflows":
  1. profiling rounds over (batch, length) to fit the latency predictor;
  2. a mixed two-task workload (code: e2e SLO / chat: TTFT+TPOT SLO);
  3. output-length predictor warmed from observed completions (Gaussian);
  4. SA priority mapping + dispatch; comparison against FCFS.

Run:  PYTHONPATH=src python examples/slo_serving.py [--n 24]
"""
import argparse

import numpy as np

from repro.core import (PAPER_TABLE2, SAParams, SLOAwareScheduler,
                        run_fcfs_continuous, run_priority_continuous)
from repro.core.profiler import OutputLengthPredictor
from repro.data.synthetic import sample_requests


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    model = PAPER_TABLE2   # V100 Qwen2.5-7B coefficients (paper Table 2)

    # --- output-length predictor warmed with historical completions
    predictor = OutputLengthPredictor(seed=args.seed)
    for r in sample_requests(300, seed=args.seed + 1):
        predictor.observe(r.task_type, r.output_len)

    reqs = sample_requests(args.n, seed=args.seed)
    print(f"workload: {args.n} requests "
          f"({sum(r.h for r in reqs)} code/e2e, "
          f"{sum(1 - r.h for r in reqs)} chat/TTFT+TPOT)")

    # --- baseline: FCFS continuous batching (vLLM-like)
    fcfs = run_fcfs_continuous(reqs, model, args.max_batch)
    print(f"FCFS      : G={fcfs.G:.4f}  attainment={fcfs.attainment:.2f}  "
          f"avg={fcfs.avg_latency:.2f}s")

    # --- SLO-aware: Algorithm 2 (predict -> assign -> anneal -> dispatch)
    sched = SLOAwareScheduler(
        model, num_instances=1, max_batch=args.max_batch,
        output_predictor=predictor,
        sa_params=SAParams(seed=args.seed, budget_mode="per_level"))
    outcome = sched.schedule(reqs)
    slo = run_priority_continuous(outcome.queues[0].batches, model,
                                  args.max_batch)
    print(f"SLO-aware : G={slo.G:.4f}  attainment={slo.attainment:.2f}  "
          f"avg={slo.avg_latency:.2f}s")
    if fcfs.G > 0:
        print(f"G improvement: {100 * (slo.G - fcfs.G) / fcfs.G:+.1f}%  |  "
              f"attainment: {fcfs.attainment:.2f} -> {slo.attainment:.2f}")
    # per-class breakdown + operator-facing percentiles
    from repro.core.metrics import report
    for task in ("code", "chat"):
        ids = [r.req_id for r in reqs if r.task_type == task]
        f_met = sum(fcfs.met[i] for i in ids)
        s_met = sum(slo.met[i] for i in ids)
        print(f"  {task}: attainment {f_met}/{len(ids)} -> {s_met}/{len(ids)}")
    rep = report(slo, reqs)
    print(f"percentiles (slo-aware): e2e p50/p90/p99 = {rep.e2e_p50:.1f}/"
          f"{rep.e2e_p90:.1f}/{rep.e2e_p99:.1f}s  ttft p90 = "
          f"{rep.ttft_p90:.1f}s  tpot p90 = {rep.tpot_p90 * 1e3:.0f}ms")


if __name__ == "__main__":
    main()
