"""H2O-Danube-1.8B [arXiv:2401.16818].

Dense llama/mistral mix with native sliding-window attention (4096),
GQA with 8 kv heads, SwiGLU.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=6912, vocab_size=32000, head_dim=80,
    sliding_window=4096,
    mlp_type="swiglu", norm_type="rmsnorm",
    source="arXiv:2401.16818",
)
