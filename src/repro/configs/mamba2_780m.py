"""Mamba2-780m [arXiv:2405.21060].

Attention-free SSM via SSD (state-space duality): d_state 128, expand 2
(d_inner 3072, 48 heads of dim 64), conv4.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    norm_type="rmsnorm",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  chunk_size=256),
    source="arXiv:2405.21060",
)
