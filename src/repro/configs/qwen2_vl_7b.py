"""Qwen2-VL-7B transformer backbone [arXiv:2409.12191].

VLM: M-RoPE (temporal/height/width sections 16/24/24 over head_dim 128),
dynamic-resolution patches arrive as precomputed embeddings from the stub
frontend (``uses_extra_embeds``); GQA with 4 kv heads.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128,
    rope_theta=1e6, mrope_sections=(16, 24, 24),
    mlp_type="swiglu", norm_type="rmsnorm", norm_eps=1e-6,
    uses_extra_embeds=True,
    source="arXiv:2409.12191",
)
