"""DBRX-base (132B total / 36B active) [hf:databricks/dbrx-base].

Fine-grained MoE: 16 experts, top-4 routing, expert FFN width 10752;
GQA with 8 kv heads over 48 query heads.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352, head_dim=128,
    rope_theta=5e5,
    mlp_type="swiglu", norm_type="rmsnorm",
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10752),
    source="hf:databricks/dbrx-base",
)
