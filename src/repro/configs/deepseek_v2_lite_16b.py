"""DeepSeek-V2-Lite (16B total / 2.4B active) [arXiv:2405.04434].

MLA (kv_lora 512, qk_nope 128 + qk_rope 64, v 128) and fine-grained MoE:
64 routed experts top-6 + 2 shared experts (expert FFN width 1408); the
first layer keeps a dense FFN (width 10944).
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=102400, head_dim=128,
    mlp_type="swiglu", norm_type="rmsnorm",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  num_shared_experts=2, dense_layers=(0,),
                  d_ff_dense=10944),
    source="arXiv:2405.04434",
)
