"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family card].

Dense: qk-norm (RMSNorm on per-head q/k), GQA with 8 kv heads, SwiGLU.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=6144, vocab_size=151936, head_dim=128,
    rope_theta=1e6, qk_norm=True,
    mlp_type="swiglu", norm_type="rmsnorm", norm_eps=1e-6,
    source="hf:Qwen/Qwen3-8B",
)
