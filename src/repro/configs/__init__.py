"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``.

The ten assigned architectures plus the paper's own evaluation model.
Every config cites its source paper / model card in its module docstring.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig, reduced

_MODULES = {
    "qwen2-vl-7b": "qwen2_vl_7b",
    "musicgen-medium": "musicgen_medium",
    "starcoder2-3b": "starcoder2_3b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "dbrx-132b": "dbrx_132b",
    "zamba2-1.2b": "zamba2_1_2b",
    "mamba2-780m": "mamba2_780m",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "qwen3-1.7b": "qwen3_1_7b",
    "qwen2.5-7b": "qwen2_5_7b",       # the paper's evaluation model
}

ASSIGNED = [k for k in _MODULES if k != "qwen2.5-7b"]


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch '{arch_id}'; known: {list(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_reduced(arch_id: str, **kw) -> ModelConfig:
    return reduced(get_config(arch_id), **kw)


def all_configs() -> Dict[str, ModelConfig]:
    return {k: get_config(k) for k in _MODULES}
