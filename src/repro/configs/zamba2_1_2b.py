"""Zamba2-1.2B [arXiv:2411.15242].

Hybrid: Mamba2 backbone with a SINGLE shared transformer (attention+MLP)
block applied periodically (weight reuse — ``shared_attn_weights``).
ssm_state 64; shared block is MHA (kv == heads) with an 8192 FFN.
"""
from repro.models.config import ModelConfig, SSMConfig

_PATTERN = tuple(
    "attn" if i % 6 == 5 else "ssm" for i in range(38)
)

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    mlp_type="swiglu", norm_type="rmsnorm",
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64),
    layer_pattern=_PATTERN,
    shared_attn_weights=True,
    source="arXiv:2411.15242",
)
