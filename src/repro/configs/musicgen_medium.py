"""MusicGen-medium decoder [arXiv:2306.05284].

Audio: decoder-only transformer over EnCodec tokens — 4 codebooks with
per-codebook embeddings summed at the input and 4 parallel logit heads
(vocab 2048 each).  MHA (kv == heads), LayerNorm + GELU as in the original
seq2seq-style stack.  Deviation noted in DESIGN.md: the original uses
sinusoidal positions; we use RoPE (TPU-idiomatic, same backbone shape).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    mlp_type="gelu", norm_type="layernorm",
    num_codebooks=4,
    source="arXiv:2306.05284",
)
