"""StarCoder2-3B [arXiv:2402.19173].

Dense code model: GQA with 2 kv heads, RoPE (theta 1e5), LayerNorm and a
non-gated GELU MLP (4x).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
    d_ff=12288, vocab_size=49152, head_dim=128,
    rope_theta=1e5,
    mlp_type="gelu", norm_type="layernorm",
    source="arXiv:2402.19173",
)
