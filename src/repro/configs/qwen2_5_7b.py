"""Qwen2.5-7B — the model the paper evaluates with [arXiv:2412.15115]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-7b", family="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064, head_dim=128,
    rope_theta=1e6,
    mlp_type="swiglu", norm_type="rmsnorm", norm_eps=1e-6,
    source="arXiv:2412.15115",
)
