"""Paged flash-decode — Pallas TPU kernel for single-token decode
attention through a block table (vLLM-style PagedAttention).

The KV cache is a shared pool of ``[num_blocks, block_size, kv, hd]``
pages; each sequence owns a row of page ids (its *block table*).  The
innermost sequential grid dimension walks the sequence's logical pages:
the scalar-prefetched block table drives the ``BlockSpec`` index map, so
each step DMAs exactly one live page from HBM into VMEM — HBM traffic is
priced by live tokens, not by the pool or the slot's worst-case length.
Online-softmax carry (max / denom / accumulator) lives in VMEM scratch;
all q heads sharing a kv head are processed together as a ``[group, hd]``
tile, exactly like the dense ``decode_attention`` kernel this extends.

Masking is by *token id* on the slot's logical ring (length
``pages_per_seq * block_size``): ring slot ``s`` holds token
``t_s = len-1 - mod(len-1-s, L)`` which is masked when negative (not yet
written) or outside the sliding window.  This makes the kernel correct
for windowed (ring) slots whose ring length was rounded up to whole
blocks.  Fully-dead pages are skipped with ``pl.when``; the caller must
clamp their table entries to a valid page id (see the wrapper).

``decode_attention_paged_q8`` is the int8-KV variant: pages are int8
with per-(token, head) bf16 scales, dequantized in VMEM right before
the MXU contractions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mask_scores(s, ln, k_start, L, window):
    """Token-id ring mask for a [g, block] score tile starting at ring
    slot ``k_start``; ``ln`` = tokens written so far (incl. current)."""
    s_idx = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    t_s = ln - 1 - jnp.mod(ln - 1 - s_idx, L)
    valid = t_s >= 0
    if window > 0:
        valid &= t_s > ln - 1 - window
    return jnp.where(valid, s, NEG_INF)


def _paged_softmax_step(load_kv, lengths_ref, q_ref, o_ref, m_scr, l_scr,
                        acc_scr, *, scale, block_size, num_pages, window):
    """Shared per-page online-softmax body: init the carry on the first
    page, attend the current page's (dequantized) K/V tile, emit the
    normalized output on the last.  ``load_kv()`` returns the page's
    float32 [P, hd] k and v tiles — the only point the float and int8
    kernels differ."""
    bi = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ln = lengths_ref[bi]
    L = num_pages * block_size
    n_valid = jnp.minimum(ln, L)
    k_start = pi * block_size

    @pl.when(k_start < n_valid)
    def _body():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale      # [g, hd]
        k, v = load_kv()                                       # [P, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [g, P]
        s = _mask_scores(s, ln, k_start, L, window)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(pi == num_pages - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def _kernel(bt_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, **static):
    def load_kv():
        return (k_ref[0, :, 0, :].astype(jnp.float32),
                v_ref[0, :, 0, :].astype(jnp.float32))
    _paged_softmax_step(load_kv, lengths_ref, q_ref, o_ref, m_scr, l_scr,
                        acc_scr, **static)


def _kernel_q8(bt_ref, lengths_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
               o_ref, m_scr, l_scr, acc_scr, **static):
    def load_kv():
        # dequantize the int8 page in VMEM right before the contractions
        return (k_ref[0, :, 0, :].astype(jnp.float32)
                * ks_ref[0, :, 0, :].astype(jnp.float32),
                v_ref[0, :, 0, :].astype(jnp.float32)
                * vs_ref[0, :, 0, :].astype(jnp.float32))
    _paged_softmax_step(load_kv, lengths_ref, q_ref, o_ref, m_scr, l_scr,
                        acc_scr, **static)


def _safe_tables(block_tables, lengths, block_size, num_blocks):
    """Clamp table entries of fully-dead pages to the null page so their
    prefetch-driven DMAs stay in-bounds (the kernel skips their math)."""
    num_pages = block_tables.shape[1]
    L = num_pages * block_size
    live = (jnp.arange(num_pages, dtype=jnp.int32)[None, :] * block_size) \
        < jnp.minimum(lengths, L)[:, None]
    bt = jnp.clip(block_tables, 0, num_blocks - 1)
    return jnp.where(live, bt, 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def decode_attention_paged(q, k_pages, v_pages, block_tables, lengths, *,
                           window: int = 0, interpret: bool = False):
    """q: [B,H,hd]; pages: [N,P,KV,hd]; block_tables: [B,pages_per_seq]
    int32; lengths: [B] int32 (context length incl. the current token)
    -> [B,H,hd]."""
    b, h, hd = q.shape
    n_blocks, P, kv = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    num_pages = block_tables.shape[1]
    g = h // kv
    scale = hd ** -0.5
    qg = q.reshape(b, kv, g, hd)
    bt = _safe_tables(block_tables, lengths, P, n_blocks)

    grid = (b, kv, num_pages)
    kernel = functools.partial(_kernel, scale=scale, block_size=P,
                               num_pages=num_pages, window=window)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, hd),
                             lambda bi, hi, pi, bt, ln: (bi, hi, 0, 0)),
                pl.BlockSpec((1, P, 1, hd),
                             lambda bi, hi, pi, bt, ln:
                             (bt[bi, pi], 0, hi, 0)),
                pl.BlockSpec((1, P, 1, hd),
                             lambda bi, hi, pi, bt, ln:
                             (bt[bi, pi], 0, hi, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd),
                                   lambda bi, hi, pi, bt, ln:
                                   (bi, hi, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
    )(bt, lengths, qg, k_pages, v_pages)
    return out.reshape(b, h, hd)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def decode_attention_paged_q8(q, k_pages, k_scale, v_pages, v_scale,
                              block_tables, lengths, *, window: int = 0,
                              interpret: bool = False):
    """int8 pages [N,P,KV,hd] + bf16 scales [N,P,KV,1]; else as
    :func:`decode_attention_paged`."""
    b, h, hd = q.shape
    n_blocks, P, kv = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    num_pages = block_tables.shape[1]
    g = h // kv
    scale = hd ** -0.5
    qg = q.reshape(b, kv, g, hd)
    bt = _safe_tables(block_tables, lengths, P, n_blocks)

    grid = (b, kv, num_pages)
    kernel = functools.partial(_kernel_q8, scale=scale, block_size=P,
                               num_pages=num_pages, window=window)
    page_spec = pl.BlockSpec((1, P, 1, hd),
                             lambda bi, hi, pi, bt, ln: (bt[bi, pi], 0, hi, 0))
    scale_spec = pl.BlockSpec((1, P, 1, 1),
                              lambda bi, hi, pi, bt, ln:
                              (bt[bi, pi], 0, hi, 0))
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, hd),
                             lambda bi, hi, pi, bt, ln: (bi, hi, 0, 0)),
                page_spec, scale_spec, page_spec, scale_spec,
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd),
                                   lambda bi, hi, pi, bt, ln:
                                   (bi, hi, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
    )(bt, lengths, qg, k_pages, k_scale, v_pages, v_scale)
    return out.reshape(b, h, hd)
