"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """q: [B,S,H,hd]; k,v: [B,S,KV,hd] (KV divides H). -> [B,S,H,hd]."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else hd ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, n_valid, *,
                         scale: float | None = None):
    """q: [B,H,hd]; caches: [B,L,KV,hd]; n_valid: [B] int32. -> [B,H,hd]."""
    b, h, hd = q.shape
    kv = k_cache.shape[2]
    rep = h // kv
    if rep > 1:
        k_cache = jnp.repeat(k_cache, rep, axis=2)
        v_cache = jnp.repeat(v_cache, rep, axis=2)
    scale = scale if scale is not None else hd ** -0.5
    scores = jnp.einsum("bhd,blhd->bhl", q.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    L = k_cache.shape[1]
    valid = jnp.arange(L)[None, :] < n_valid[:, None]
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhl,blhd->bhd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)


def decode_attention_paged_ref(q, k_pages, v_pages, block_tables, lengths,
                               *, window: int = 0,
                               scale: float | None = None):
    """Paged flash-decode oracle: gather live pages into the logical
    [B, L, kv, hd] view, then token-id ring masking.

    q: [B,H,hd]; pages: [N,P,KV,hd]; block_tables: [B,pages_per_seq];
    lengths: [B] int32 (context length incl. current token). -> [B,H,hd].
    """
    b, h, hd = q.shape
    P = k_pages.shape[1]
    kv = k_pages.shape[2]
    num_pages = block_tables.shape[1]
    L = num_pages * P
    k_cache = k_pages[block_tables].reshape(b, L, kv, hd)
    v_cache = v_pages[block_tables].reshape(b, L, kv, hd)
    rep = h // kv
    if rep > 1:
        k_cache = jnp.repeat(k_cache, rep, axis=2)
        v_cache = jnp.repeat(v_cache, rep, axis=2)
    scale = scale if scale is not None else hd ** -0.5
    scores = jnp.einsum("bhd,blhd->bhl", q.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    # ring slot s holds token t_s = len-1 - mod(len-1-s, L); mask slots
    # not yet written (t_s < 0) and, for windowed archs, evicted tokens
    ln = lengths[:, None]
    s_idx = jnp.arange(L, dtype=jnp.int32)[None, :]
    t_s = ln - 1 - jnp.mod(ln - 1 - s_idx, L)
    valid = t_s >= 0
    if window > 0:
        valid &= t_s > ln - 1 - window
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhl,blhd->bhd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x, dt, A, B, C, chunk: int, init_state=None):
    """Sequential (non-chunked) SSD recurrence oracle.

    x: [b,s,nh,hd]; dt: [b,s,nh]; A: [nh]; B, C: [b,s,ds].
    Returns (y [b,s,nh,hd], final_state [b,nh,hd,ds]).
    """
    b, s, nh, hd = x.shape
    ds = B.shape[-1]
    f32 = jnp.float32
    x, dt, B, C = (a.astype(f32) for a in (x, dt, B, C))
    state = (jnp.zeros((b, nh, hd, ds), f32) if init_state is None
             else init_state.astype(f32))

    def step(state, inp):
        xt, dtt, Bt, Ct = inp          # [b,nh,hd], [b,nh], [b,ds], [b,ds]
        decay = jnp.exp(dtt * A[None, :])
        upd = jnp.einsum("bh,bhp,bd->bhpd", dtt, xt, Bt)
        state = state * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpd,bd->bhp", state, Ct)
        return state, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    final, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), final
