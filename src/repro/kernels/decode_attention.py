"""Flash-decode — Pallas TPU kernel for single-token decode attention.

One query token per sequence attends to a long KV cache.  The KV length is
tiled into VMEM-resident blocks iterated as the innermost sequential grid
dimension with an online-softmax carry (max / denom / accumulator) in VMEM
scratch.  All q heads sharing a kv head are processed together as a
[group, hd] tile, so GQA costs one cache read per kv head (the
memory-bound term that dominates decode).

The per-sequence valid length arrives via scalar prefetch (SMEM) and masks
the tail block; fully-invalid blocks are skipped with ``pl.when``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(n_valid_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale, block_k, num_kb):
    bi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    n_valid = n_valid_ref[bi]
    k_start = ki * block_k

    @pl.when(k_start < n_valid)
    def _body():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale      # [g, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)              # [bk, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [g, bk]
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kpos < n_valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(ki == num_kb - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, n_valid, *, block_k: int = 256,
                     interpret: bool = False):
    """q: [B,H,hd]; caches: [B,L,KV,hd]; n_valid: [B] int32 -> [B,H,hd]."""
    b, h, hd = q.shape
    L, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv                 # q heads per kv head
    block_k = min(block_k, L)
    assert L % block_k == 0
    num_kb = L // block_k
    scale = hd ** -0.5
    # group q heads: [B, KV, g, hd]
    qg = q.reshape(b, kv, g, hd)

    grid = (b, kv, num_kb)
    kernel = functools.partial(_kernel, scale=scale, block_k=block_k,
                               num_kb=num_kb)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, hd),
                             lambda bi, hi, ki, nv: (bi, hi, 0, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda bi, hi, ki, nv: (bi, ki, hi, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda bi, hi, ki, nv: (bi, ki, hi, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd),
                                   lambda bi, hi, ki, nv: (bi, hi, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        interpret=interpret,
    )(n_valid, qg, k_cache, v_cache)
    return out.reshape(b, h, hd)
