"""Public jit'd wrappers for the Pallas kernels.

Dispatch policy: on TPU backends the Pallas kernels run compiled; on CPU
(this container) they run in ``interpret=True`` mode or fall back to the
pure-jnp reference — selectable via ``set_kernel_mode``.  The model code
calls these wrappers so swapping implementations never touches call sites.
"""
from __future__ import annotations

from typing import Literal

import jax

from repro.kernels import ref as _ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.decode_attention_paged import (
    decode_attention_paged as _decode_paged_pallas,
    decode_attention_paged_q8 as _decode_paged_q8_pallas)
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas

Mode = Literal["auto", "pallas", "interpret", "ref"]
_MODE: Mode = "auto"
_TP_SHARDS: int = 1


def set_kernel_mode(mode: Mode):
    global _MODE
    _MODE = mode


def set_tp_shards(n: int):
    """Declare the tensor-parallel shard count the cache pages live under.

    ``pallas_call`` does not auto-partition under GSPMD — running the paged
    Pallas kernel inside a tp>1 jit would force XLA to gather the full page
    pool onto every device.  Until the kernel is wrapped in ``shard_map``
    (real-TPU follow-up, see docs/sharding.md), the paged dispatchers route
    to the pure-jnp gather reference, which the partitioner shards on the
    head axis automatically.
    """
    global _TP_SHARDS
    _TP_SHARDS = max(1, int(n))


def _resolved() -> str:
    if _MODE != "auto":
        return _MODE
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _paged_resolved() -> str:
    mode = _resolved()
    if _TP_SHARDS > 1 and mode in ("pallas", "interpret"):
        return "ref"
    return mode


def flash_attention(q, k, v, *, causal=True, window=0,
                    block_q=128, block_k=128):
    mode = _resolved()
    if mode == "ref":
        return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         block_q=block_q, block_k=block_k,
                         interpret=(mode == "interpret"))


def decode_attention(q, k_cache, v_cache, n_valid, *, block_k=256):
    mode = _resolved()
    if mode == "ref":
        return _ref.decode_attention_ref(q, k_cache, v_cache, n_valid)
    return _decode_pallas(q, k_cache, v_cache, n_valid, block_k=block_k,
                          interpret=(mode == "interpret"))


def decode_attention_paged(q, k_pages, v_pages, block_tables, lengths, *,
                           window=0):
    """Flash-decode through a block table (paged KV pool)."""
    mode = _paged_resolved()
    if mode == "ref":
        return _ref.decode_attention_paged_ref(q, k_pages, v_pages,
                                               block_tables, lengths,
                                               window=window)
    return _decode_paged_pallas(q, k_pages, v_pages, block_tables, lengths,
                                window=window,
                                interpret=(mode == "interpret"))


def decode_attention_paged_q8(q, k_pages, k_scale, v_pages, v_scale,
                              block_tables, lengths, *, window=0):
    """int8-KV paged flash-decode (per-(token, head) bf16 scales)."""
    mode = _paged_resolved()
    if mode == "ref":
        from repro.models.cache import dequantize_kv
        kf = dequantize_kv(k_pages, k_scale)
        vf = dequantize_kv(v_pages, v_scale)
        return _ref.decode_attention_paged_ref(q, kf, vf, block_tables,
                                               lengths, window=window)
    return _decode_paged_q8_pallas(q, k_pages, k_scale, v_pages, v_scale,
                                   block_tables, lengths, window=window,
                                   interpret=(mode == "interpret"))


def ssd_scan(x, dt, A, B, C, *, chunk=64):
    mode = _resolved()
    if mode == "ref":
        return _ref.ssd_ref(x, dt, A, B, C, chunk=chunk)
    return _ssd_pallas(x, dt, A, B, C, chunk=chunk,
                       interpret=(mode == "interpret"))
