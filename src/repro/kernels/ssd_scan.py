"""Mamba2 SSD chunk scan — Pallas TPU kernel.

The SSD duality splits the scan into a quadratic intra-chunk part (an
attention-like [q, q] matmul that feeds the MXU) and a linear inter-chunk
state recurrence.  The kernel iterates chunks as the innermost sequential
grid dimension, carrying the [hd, ds] recurrent state in VMEM scratch —
the TPU analogue of the Triton chunk kernel's cross-CTA state passing
(which has no direct equivalent: TPU grids are sequential, so the carry is
simply scratch that survives grid steps).

Grid: (batch, heads, num_chunks).  Per step, tiles in VMEM:
  x  [q, hd], dt [q], B/C [q, ds], state [hd, ds] (f32 scratch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_out_ref, st_scr,
            *, chunk, num_chunks):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        st_scr[...] = jnp.zeros_like(st_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # [q, hd]
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # [q]
    A = a_ref[0]                                   # scalar (negative)
    Bm = b_ref[0, :, :].astype(jnp.float32)        # [q, ds]
    Cm = c_ref[0, :, :].astype(jnp.float32)        # [q, ds]

    dA = dt * A                                    # [q]
    cum = jnp.cumsum(dA)                           # [q] log-decay within chunk

    # ----- intra-chunk quadratic part (MXU matmuls)
    li = cum[:, None]
    lj = cum[None, :]
    iot = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jot = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tril = jot <= iot
    L = jnp.exp(jnp.where(tril, li - lj, -1e30))   # [q, q]
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # [q, q]
    att = cb * L
    xdt = x * dt[:, None]                          # [q, hd]
    y_intra = jax.lax.dot_general(att, xdt, (((1,), (0,)), ((), ())))

    # ----- inter-chunk contribution from the carried state
    state = st_scr[...]                            # [hd, ds]
    in_decay = jnp.exp(cum)[:, None]               # [q, 1]
    y_inter = jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ()))) * in_decay   # [q, hd]

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # ----- state update: S <- decay_chunk * S + sum_j d2e_j dt_j x_j B_j^T
    decay_to_end = jnp.exp(cum[-1] - cum)          # [q]
    w = (decay_to_end * dt)[:, None] * x           # [q, hd]
    upd = jax.lax.dot_general(w, Bm, (((0,), (0,)), ((), ())))  # [hd, ds]
    chunk_decay = jnp.exp(jnp.sum(dA))
    st_scr[...] = state * chunk_decay + upd

    @pl.when(ci == num_chunks - 1)
    def _emit():
        st_out_ref[0, 0, :, :] = st_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 64, interpret: bool = False):
    """x: [b,s,nh,hd]; dt: [b,s,nh] (post-softplus); A: [nh] negative;
    B, C: [b,s,ds].  Returns (y [b,s,nh,hd], final_state [b,nh,hd,ds]).
    """
    b, s, nh, hd = x.shape
    ds = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    grid = (b, nh, nc)

    kernel = functools.partial(_kernel, chunk=chunk, num_chunks=nc)
    y, final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, ds), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, ds), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, hd), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, hd, ds), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((b, nh, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), B, C)
    return y, final
