"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
a pure-jnp oracle in ref.py, and a jit'd dispatching wrapper in ops.py.
Validated in interpret=True mode on CPU (tests/test_kernels.py); compiled
on TPU backends.

  flash_attention       prefill attention (causal, sliding-window, GQA)
  flash_attention_vjp   differentiable variant (custom_vjp Pallas backward)
  decode_attention      flash-decode: one token vs a long KV cache
  decode_attention_q8   flash-decode over an int8-quantized KV cache
  ssd_scan              Mamba2 SSD chunk scan with VMEM state carry
"""
from repro.kernels import ops, ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.decode_attention_q8 import decode_attention_q8
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention_bwd import flash_attention_vjp
from repro.kernels.ssd_scan import ssd_scan

__all__ = ["ops", "ref", "decode_attention", "decode_attention_q8",
           "flash_attention", "flash_attention_vjp", "ssd_scan"]
