"""Flash-attention backward — Pallas TPU kernels + custom_vjp wrapper.

Forward saves the per-row log-sum-exp (lse) and output; backward recomputes
attention probabilities blockwise from (q, k, lse) — the standard
flash-attention-2 recomputation strategy, adapted to TPU grids:

  * dq kernel: grid (b, h, q_blocks, k_blocks) — k is the sequential inner
    dim, dq accumulates in VMEM scratch across k steps.
  * dkv kernel: grid (b, kv_head, k_blocks, q_blocks) — q is the sequential
    inner dim, dk/dv accumulate in scratch; GQA query heads of one kv head
    are folded into the q-block loop (dk/dv sum over the group).

``flash_attention_vjp`` exposes the differentiable op; gradients validate
against ``jax.grad`` of the jnp oracle in interpret mode (tests).
MHA/GQA supported; softcap not supported (falls back to XLA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ------------------------------------------------------------- forward
def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr,
                *, scale, block_q, block_k, causal, window, num_kb):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1

    @pl.when(run if isinstance(run, jax.Array) else bool(run))
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(ki == num_kb - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0, :] = m_scr[...] + jnp.log(l)


def _fwd(q, k, v, *, causal, window, block_q, block_k, interpret):
    b, s, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    num_qb, num_kb = s // block_q, s // block_k
    scale = hd ** -0.5
    kernel = functools.partial(_fwd_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, causal=causal, window=window,
                               num_kb=num_kb)
    out, lse = pl.pallas_call(
        kernel,
        grid=(b, h, num_qb, num_kb),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bi, hi, qi, ki: (bi, ki, hi // rep, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bi, hi, qi, ki: (bi, ki, hi // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda bi, hi, qi, ki: (bi, hi, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out, lse


# ------------------------------------------------------------- backward
def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_scr, *, scale, block_q, block_k, causal, window, num_kb):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1

    @pl.when(run if isinstance(run, jax.Array) else bool(run))
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, 0, :]
        delta = delta_ref[0, 0, :]
        s = jax.lax.dot_general(q * scale, k,
                                (((1,), (1,)), ((), ())))    # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta[:, None]) * scale
        acc_scr[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())))

    @pl.when(ki == num_kb - 1)
    def _finish():
        dq_ref[0, :, 0, :] = acc_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr,
                *, scale, block_q, block_k, causal, window, num_qb, rep):
    ki = pl.program_id(2)
    qi = pl.program_id(3) // rep      # q-block index
    ri = pl.program_id(3) % rep       # query-head-in-group index  (unused:
    #                                   head selection happens via BlockSpec)

    @pl.when(pl.program_id(3) == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q_start = qi * block_q
    k_start = ki * block_k
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1

    @pl.when(run if isinstance(run, jax.Array) else bool(run))
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        do = do_ref[0, :, 0, :].astype(jnp.float32)
        lse = lse_ref[0, 0, :]
        delta = delta_ref[0, 0, :]
        s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())))
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)   # [bq, bk]
        dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta[:, None]) * scale                # [bq, bk]
        dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(pl.program_id(3) == num_qb * rep - 1)
    def _finish():
        dk_ref[0, :, 0, :] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_scr[...].astype(dv_ref.dtype)


def _bwd(res, dout, *, causal, window, block_q, block_k, interpret):
    q, k, v, out, lse = res
    b, s, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    bq = min(block_q, s)
    bk = min(block_k, s)
    num_qb, num_kb = s // bq, s // bk
    scale = hd ** -0.5
    delta = jnp.sum(out.astype(jnp.float32) * dout.astype(jnp.float32),
                    axis=-1)                                  # [b, s, h]
    delta = jnp.moveaxis(delta, -1, 1)                        # [b, h, s]

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, block_q=bq, block_k=bk,
                          causal=causal, window=window, num_kb=num_kb),
        grid=(b, h, num_qb, num_kb),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda bi, hi, qi, ki: (bi, ki, hi // rep, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda bi, hi, qi, ki: (bi, ki, hi // rep, 0)),
            pl.BlockSpec((1, bq, 1, hd), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, 1, bq), lambda bi, hi, qi, ki: (bi, hi, qi)),
            pl.BlockSpec((1, 1, bq), lambda bi, hi, qi, ki: (bi, hi, qi)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)

    # dk/dv: iterate (q_block, group_head) as the sequential inner dim
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, block_q=bq, block_k=bk,
                          causal=causal, window=window, num_qb=num_qb,
                          rep=rep),
        grid=(b, kv, num_kb, num_qb * rep),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd),
                         lambda bi, gi, ki, qr: (bi, qr // rep,
                                                 gi * rep + qr % rep, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda bi, gi, ki, qr: (bi, ki, gi, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda bi, gi, ki, qr: (bi, ki, gi, 0)),
            pl.BlockSpec((1, bq, 1, hd),
                         lambda bi, gi, ki, qr: (bi, qr // rep,
                                                 gi * rep + qr % rep, 0)),
            pl.BlockSpec((1, 1, bq),
                         lambda bi, gi, ki, qr: (bi, gi * rep + qr % rep,
                                                 qr // rep)),
            pl.BlockSpec((1, 1, bq),
                         lambda bi, gi, ki, qr: (bi, gi * rep + qr % rep,
                                                 qr // rep)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, 1, hd),
                         lambda bi, gi, ki, qr: (bi, ki, gi, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda bi, gi, ki, qr: (bi, ki, gi, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(k.shape, k.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, hd), jnp.float32),
                        pltpu.VMEM((bk, hd), jnp.float32)],
        interpret=interpret,
    )(q, k, v, dout, lse, delta)
    return dq, dk, dv


# ------------------------------------------------------------- custom vjp
@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_vjp(q, k, v, causal=True, window=0, block_q=128,
                        block_k=128, interpret=False):
    """Differentiable flash attention. Same contract as flash_attention."""
    out, _ = _fwd(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=interpret)
    return out


def _vjp_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    out, lse = _fwd(q, k, v, causal=causal, window=window, block_q=block_q,
                    block_k=block_k, interpret=interpret)
    return out, (q, k, v, out, lse)


def _vjp_bwd(causal, window, block_q, block_k, interpret, res, dout):
    return _bwd(res, dout, causal=causal, window=window, block_q=block_q,
                block_k=block_k, interpret=interpret)


flash_attention_vjp.defvjp(_vjp_fwd, _vjp_bwd)
