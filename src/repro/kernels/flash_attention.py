"""Prefill flash attention — Pallas TPU kernel.

Online-softmax flash attention with causal and sliding-window masking and
GQA (q-head → kv-head mapping via BlockSpec index maps; no materialized
head repetition).

TPU adaptation (vs the CUDA flash-attention formulation):
  * tiles live in VMEM; ``block_q × head_dim`` and ``block_k × head_dim``
    are chosen as multiples of the 128-lane MXU tiling;
  * the k-loop is the innermost *sequential* grid dimension, carrying the
    running max / denominator / accumulator in VMEM scratch across grid
    steps (TPU grids iterate sequentially, so cross-step scratch is sound —
    the idiom replaces CUDA's in-kernel loop + shared memory);
  * fully-masked key blocks are skipped with ``pl.when`` (the causal /
    window structure is known from block indices alone).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, block_q, block_k, seq_len, causal, window, num_kb):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # does this (q_block, k_block) pair contain any unmasked entry?
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window > 0:
        # newest q position is q_start+block_q-1; oldest allowed key is
        # q_start - window + 1; block dead if its last key is older.
        run = jnp.logical_and(run, k_start + block_k - 1
                              > q_start - window) if window else run

    @pl.when(run if isinstance(run, jax.Array) else bool(run))
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale     # [bq, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # [bk, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(ki == num_kb - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: [B,S,H,hd]; k,v: [B,S,KV,hd]. Returns [B,S,H,hd]."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0
    num_qb = s // block_q
    num_kb = s // block_k
    scale = hd ** -0.5

    grid = (b, h, num_qb, num_kb)
    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_len=s, causal=causal, window=window, num_kb=num_kb)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bi, hi, qi, ki: (bi, ki, hi // rep, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bi, hi, qi, ki: (bi, ki, hi // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
