"""Host-side block accounting for the paged KV pool.

The device side is a shared page array per layer plus per-slot block
tables (``models/cache.init_paged_cache``); this module owns the
free-list over page ids.  Block 0 is the **null page** — reserved as the
scatter/gather target for dead slots and padded prefill tokens — so real
allocations hand out ids ``1..num_blocks-1``.

The pool's occupancy is the scheduler signal: the engine exposes
``available``/``total`` through ``SchedulerView.free_blocks`` /
``total_blocks`` so admission and preemption can be memory-aware.
"""
from __future__ import annotations

from typing import List


class BlockPool:
    """Free-list allocator over ``num_blocks`` KV pages (id 0 reserved)."""

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("num_blocks must be >= 2 (block 0 is the "
                             "null page)")
        self.num_blocks = num_blocks
        # LIFO free list: recently-freed (cache-warm) pages are reused first
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._held: set = set()

    @property
    def total(self) -> int:
        """Allocatable blocks (excludes the null page)."""
        return self.num_blocks - 1

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._held)

    def alloc(self, n: int) -> List[int]:
        """Pop ``n`` block ids; raises if the pool cannot cover them —
        callers must check ``available`` first (admission refusal)."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n > len(self._free):
            raise RuntimeError(
                f"out of KV blocks: need {n}, {len(self._free)} free "
                f"of {self.total}")
        ids = [self._free.pop() for _ in range(n)]
        self._held.update(ids)
        return ids

    def free(self, ids: List[int]) -> None:
        for i in ids:
            if i not in self._held:
                raise ValueError(f"block {i} is not allocated "
                                 "(double free or foreign id)")
            self._held.remove(i)
            self._free.append(i)
