"""Host-side block accounting for the paged KV pool.

The device side is a shared page array per layer plus per-slot block
tables (``models/cache.init_paged_cache``); this module owns the
free-list over page ids.  Block 0 is the **null page** — reserved as the
scatter/gather target for dead slots and padded prefill tokens — so real
allocations hand out ids ``1..num_blocks-1``.

Ownership is **refcounted** (vLLM/SGLang-style prefix sharing): a block
freshly popped by :meth:`BlockPool.alloc` has refcount 1; every extra
owner — another request aliasing the same cached prefix, or the radix
prefix index pinning a block — calls :meth:`share`; :meth:`release`
decrements and returns the block to the free list only at refcount 0.
The PR 5 exclusive-ownership :meth:`free` survives as a deprecation
shim: it is exactly ``release`` on refcount-1 blocks and warns when a
caller "frees" a block that still has other owners.

The pool's occupancy is the scheduler signal: the engine exposes
``available``/``total`` through ``SchedulerView.free_blocks`` /
``total_blocks`` so admission and preemption can be memory-aware.
"""
from __future__ import annotations

import warnings
from collections import Counter
from typing import Dict, List


class BlockPool:
    """Refcounted free-list allocator over ``num_blocks`` KV pages
    (id 0 reserved)."""

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("num_blocks must be >= 2 (block 0 is the "
                             "null page)")
        self.num_blocks = num_blocks
        # LIFO free list: recently-freed (cache-warm) pages are reused first
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._ref: Dict[int, int] = {}

    @property
    def total(self) -> int:
        """Allocatable blocks (excludes the null page)."""
        return self.num_blocks - 1

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        """Blocks with at least one owner (refcount >= 1)."""
        return len(self._ref)

    @property
    def shared(self) -> int:
        """Blocks with more than one owner (refcount >= 2)."""
        return sum(1 for c in self._ref.values() if c > 1)

    def refcount(self, block_id: int) -> int:
        """Owners of ``block_id`` (0: free or foreign)."""
        return self._ref.get(block_id, 0)

    def alloc(self, n: int) -> List[int]:
        """Pop ``n`` block ids at refcount 1; raises if the pool cannot
        cover them — callers must check ``available`` first (admission
        refusal)."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} blocks")
        if n > len(self._free):
            raise RuntimeError(
                f"out of KV blocks: need {n}, {len(self._free)} free "
                f"of {self.total}")
        ids = [self._free.pop() for _ in range(n)]
        for i in ids:
            self._ref[i] = 1
        return ids

    def share(self, ids: List[int]) -> None:
        """Add one owner to each block (prefix aliasing / index pin).
        All ids must be live; validated before any refcount changes."""
        for i in ids:
            if i not in self._ref:
                raise ValueError(f"cannot share block {i}: not allocated")
        for i in ids:
            self._ref[i] += 1

    def release(self, ids: List[int]) -> None:
        """Drop one owner per listed block; a block returns to the free
        list when its last owner releases it.  A block listed k times is
        released k times (its refcount must cover the multiplicity) —
        the whole call is validated before any state changes."""
        need = Counter(ids)
        for i, k in need.items():
            have = self._ref.get(i, 0)
            if have < k:
                raise ValueError(
                    f"cannot release block {i} x{k}: refcount {have} "
                    "(double free or foreign id)")
        for i in ids:
            self._ref[i] -= 1
            if self._ref[i] == 0:
                del self._ref[i]
                self._free.append(i)

    def free(self, ids: List[int]) -> None:
        """PR 5 exclusive-ownership API (deprecation shim).

        Exactly :meth:`release` for refcount-1 blocks — the fast path old
        callers hit.  The exclusive-pool invariants it used to assume are
        now validated *atomically*: duplicate ids in one call or a
        non-live id raise ``ValueError`` before any mutation (the old
        implementation appended to the free list as it walked, so a
        duplicate corrupted the free list mid-call).  Freeing a block
        other owners still hold is no longer a full free — it warns and
        decrements, like ``release``."""
        seen = set()
        for i in ids:
            if i in seen:
                raise ValueError(
                    f"block {i} listed twice in one free() call")
            seen.add(i)
            if i not in self._ref:
                raise ValueError(f"block {i} is not allocated "
                                 "(double free or foreign id)")
        if any(self._ref[i] > 1 for i in ids):
            warnings.warn(
                "BlockPool.free() on a shared block: exclusive ownership "
                "is gone (refcounted pages); the call decrements the "
                "refcount like release(). Call release() directly.",
                DeprecationWarning, stacklevel=2)
        self.release(ids)
