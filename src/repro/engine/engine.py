"""Slot-based continuous-batching serving engine over the JAX model.

Static-shape design (TPU-friendly): a fixed pool of ``max_slots`` request
slots; prefills are padded to power-of-two length buckets; the decode
step always runs over the full slot pool with inactive slots masked.

KV memory comes in two layouts.  The default is a **block-paged pool**
(vLLM-style): one shared ``[num_blocks, block_size, ...]`` page array
per layer plus per-slot block tables, sized in *tokens* rather than
``max_slots × max_seq_len``.  Prefill K/V is written *in place* into the
slot's pages (an O(prompt) scatter under jit buffer donation — no
per-prefill full-length cache allocation and no O(pool) commit copy),
blocks are allocated on admit and freed on finish/preempt, and decode
attends through the block table (the Pallas paged flash-decode kernel on
TPU).  Admission is memory-aware: a request is admitted only while free
blocks cover its prompt + output budget, and the block-pool occupancy is
exposed to policies through ``SchedulerView.free_blocks``.
``paged=False`` restores the dense ``max_slots × max_seq_len`` layout
(kept for comparison benchmarks); SSM-only archs always use it — their
state is O(1) in sequence length, so there is nothing to page.

Scheduling is delegated to the v2 API (:mod:`repro.core.policies`):
``run_policy`` accepts any :class:`SchedulingPolicy` — the same objects
that drive the discrete-event core — builds a :class:`SchedulerView` of
the pending and running sets each step, and honors admit *and* preempt
decisions (evicted requests lose their KV and are re-prefilled over
prompt + generated tokens).  ``run_fcfs`` / ``run_planned`` /
``run_priority`` are thin wrappers over it.

Execution is plan-driven (chunk-as-tick): each round the active
:class:`ExecutionDiscipline` emits a :class:`StepPlan` — one prefill
span per slot mid-prefill (``Phase.PREFILLING``, staged by
``begin_prefill``) plus one decode item per running slot — and
``execute_step`` advances the prefill spans then runs a single decode
round, so under ``ChunkedPrefill(n)`` a long prompt's chunks ride the
same ticks as the running decodes (Sarathi-style mixed batches) while
``StallingPrefill`` completes each prefill in one tick.  ``run_policy``,
the discrete-event core and the streaming ``ServeLoop`` are all thin
drivers of this one plan/execute cycle.

Every prefill/decode step is timed and fed to the ``LatencyProfiler`` so
the paper's linear latency model can be fit from *this* engine's behaviour
(hardware adaptation: coefficients are re-fit per device type).
"""
from __future__ import annotations

import time
import warnings
from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.latency_model import LinearLatencyModel
from repro.core.policies import (ChunkedPrefill, ExecutionDiscipline,
                                 FCFSPolicy, PlannedPolicy, SchedulerView,
                                 SchedulingPolicy, StallingPrefill,
                                 StepPlan, make_active_view,
                                 make_discipline, normalize_decision,
                                 resolve_policy)
from repro.core.profiler import LatencyProfiler
from repro.core.slo import meets_slo
from repro.engine.blocks import BlockPool
from repro.engine.prefix import RadixPrefixIndex
from repro.engine.request import Phase, RuntimeRequest
from repro.engine.sampling import sample
from repro.models.cache import (copy_page, init_cache, init_paged_cache,
                                paged_slot_len)
from repro.models.config import ModelConfig
from repro.models.model import (forward_chunk, forward_chunk_paged,
                                forward_decode, forward_decode_paged,
                                forward_full, forward_prefill_paged)


def _bucket(n: int, lo: int = 16) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_slots: int = 8,
                 max_seq_len: int = 512, eos_token: int = -1,
                 temperature: float = 0.0, seed: int = 0,
                 profiler: Optional[LatencyProfiler] = None,
                 chunked_prefill: int = 0, paged: Optional[bool] = None,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 prefix_cache: bool = True, mesh=None, parallelism=None):
        """chunked_prefill > 0: split prompts into chunks of that size and
        interleave each chunk with a decode round for the running slots
        (Sarathi-style — new prompts no longer stall running decodes for
        their whole prefill).  Unsupported for MLA archs (falls back).

        ``paged`` (default: True whenever the arch has attention layers)
        selects the block-paged KV pool: ``num_blocks`` pages of
        ``block_size`` tokens each (+ the reserved null page), defaulting
        to the dense layout's capacity of ``max_slots`` full-length
        slots.  Shrinking ``num_blocks`` trades HBM for admission
        capacity — admission refuses requests whose prompt + output
        budget exceeds the free blocks.

        ``prefix_cache`` (default on for paged pure-attention archs)
        enables shared-prefix KV reuse: finished/prefilled prompts are
        indexed block-by-block in a radix trie, arriving prompts alias
        the longest cached block-aligned prefix (refcounted pages) and
        prefill only the unique suffix.  Divergent writes into a shared
        page copy-on-write.  Disabled automatically for SSM/hybrid
        (recurrent state is not block-addressable), MLA and
        sliding-window archs.

        ``mesh`` (a ``jax.sharding.Mesh``, e.g. from
        ``repro.launch.mesh.make_host_mesh``) turns on tensor-parallel
        SPMD execution: params shard per ``distributed.sharding.
        param_specs`` and the paged page arrays shard on the kv-head
        axis (``cache_specs`` paged layout) while ``pos`` /
        ``block_tables`` stay replicated, so every host-side path —
        BlockPool accounting, prefix reuse, copy-on-write — is
        untouched.  The jitted step fns pin their outputs
        (``out_shardings``): logits/tokens replicated, cache on its
        sharding, which also keeps buffer donation exact.  Requires the
        paged layout.  ``parallelism`` overrides the
        :class:`~repro.distributed.sharding.ParallelismConfig` (default:
        tp on the ``model`` axis, no FSDP — serving replicates what it
        cannot head-shard)."""
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self.eos = eos_token
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.profiler = profiler
        self.clock = 0.0             # engine-internal wall clock
        if paged is None:
            paged = bool(cfg.attn_layers)
        self.paged = paged and bool(cfg.attn_layers)
        self.slot_free = [True] * max_slots
        self.slot_req: List[Optional[RuntimeRequest]] = [None] * max_slots
        if self.paged:
            self.block_size = block_size
            self.slot_len = paged_slot_len(cfg, max_seq_len, block_size)
            self.pages_per_slot = self.slot_len // block_size
            if num_blocks is None:
                num_blocks = max_slots * self.pages_per_slot + 1
            self.num_blocks = num_blocks
            self.pool = BlockPool(num_blocks)
            self._slot_blocks: List[List[int]] = [[] for _ in
                                                  range(max_slots)]
            self.cache = init_paged_cache(cfg, max_slots, max_seq_len,
                                          num_blocks, block_size)
            jit_kw = self._init_mesh(mesh, parallelism)
            # the paged step fns donate the cache: page writes are
            # in-place scatters, never O(pool) copies (out_shardings
            # matching the committed input keeps donation exact under
            # a mesh)
            self._decode_fn = jax.jit(self._decode_step_paged,
                                      donate_argnums=(1,), **jit_kw)
            self._prefill_fn = jax.jit(self._prefill_paged,
                                       donate_argnums=(1,), **jit_kw)
            self._chunk_fn = jax.jit(self._prefill_chunk_paged,
                                     donate_argnums=(1,), **jit_kw)
            # prefix sharing needs position-faithful, block-addressable
            # KV: pure full-attention archs only
            self.prefix = RadixPrefixIndex(self.pool, block_size) \
                if (prefix_cache and not cfg.ssm_layers
                    and cfg.mla is None and not cfg.sliding_window) \
                else None
        else:
            if mesh is not None:
                raise ValueError(
                    "mesh execution requires the paged KV layout "
                    "(paged=True with an attention arch)")
            self.mesh = None
            self._jit_kw = {}
            self.pool = None
            self.prefix = None
            # slot pool: one batched dense cache over all slots
            self.cache = init_cache(cfg, max_slots, max_seq_len)
            self._decode_fn = jax.jit(self._decode_step)
            self._prefill_fn = jax.jit(self._prefill_one)  # per bucket
            self._chunk_fn = jax.jit(self._prefill_chunk)
        self.chunked_prefill = 0 if cfg.mla is not None else chunked_prefill
        # dense-mode in-progress prefills: slot -> private single-slot
        # cache, committed to the pool when the final chunk completes
        self._partial: Dict[int, object] = {}
        self._warm = set()
        self.cow_copies = 0          # copy-on-write page splits performed
        # fused decode+sample dispatch path (serving loop): one jit, one
        # compilation per pow-2 batch width; donation only in paged mode
        # (the dense step merges with `where`, allocating fresh arrays)
        if self.paged:
            self._dispatch_fn = jax.jit(self._decode_dispatch_paged,
                                        donate_argnums=(1,),
                                        **self._jit_kw)
        else:
            self._dispatch_fn = jax.jit(self._decode_dispatch_dense)

    # ------------------------------------------------------------- mesh
    def _init_mesh(self, mesh, parallelism):
        """Commit params and the paged cache to their NamedShardings and
        build the ``out_shardings`` kwargs the step jits pin outputs
        with (logits/sampled tokens replicated — sampling and the host
        scheduling paths read them — cache on its head-sharded specs).

        The Pallas paged-decode kernel does not partition under GSPMD,
        so ``ops.set_tp_shards`` reroutes paged attention to the
        pure-jnp gather reference whenever tp > 1 — XLA shards that on
        the kv-head axis automatically (a ``shard_map`` wrap of the
        kernel is the real-TPU follow-up; see docs/sharding.md).
        """
        self.mesh = mesh
        self._jit_kw = {}
        if mesh is None:
            return self._jit_kw
        from repro.distributed.sharding import (ParallelismConfig,
                                                cache_specs, named,
                                                param_specs)
        from repro.kernels import ops
        par = parallelism if parallelism is not None \
            else ParallelismConfig(fsdp=False)
        self.parallelism = par
        self.params = jax.device_put(
            self.params,
            named(mesh, param_specs(self.params, self.cfg, mesh, par)))
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.cache)
        self._cache_shardings = named(
            mesh, cache_specs(shapes, self.cfg, mesh, par, self.max_slots))
        self._repl = NamedSharding(mesh, P())
        self.cache = jax.device_put(self.cache, self._cache_shardings)
        ops.set_tp_shards(mesh.shape[par.tp_axis])
        self._jit_kw = {
            "out_shardings": (self._repl, self._cache_shardings)}
        return self._jit_kw

    def _commit(self, cache):
        """Re-commit a cache pytree to its shardings.  Host round-trips
        (``_warm_paged`` restore) produce uncommitted single-device
        arrays that would silently violate the jits' donation/sharding
        contract under a mesh; a no-op without one."""
        if getattr(self, "mesh", None) is None:
            return cache
        return jax.device_put(cache, self._cache_shardings)

    # ------------------------------------------------------------ jitted
    def _decode_step(self, params, cache, tokens, active):
        """tokens [B,1]; active [B] bool; returns (logits [B,V], cache)."""
        logits, new_cache = forward_decode(params, self.cfg, tokens=tokens,
                                           cache=new_cache_arg(cache))
        # freeze caches of inactive slots
        def keep(new, old):
            mask = active.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(mask, new, old)
        merged = jax.tree.map(keep, new_cache, cache)
        merged["pos"] = jnp.where(active, new_cache["pos"], cache["pos"])
        return logits[:, -1], merged

    def _prefill_chunk(self, params, cache1, tokens):
        """One chunk continuation over a single-slot cache."""
        return forward_chunk(params, self.cfg, tokens=tokens, cache=cache1)

    def _prefill_one(self, params, tokens, length):
        """tokens [1, Lpad]; length: actual length. Single-slot prefill."""
        cache = init_cache(self.cfg, 1, self.max_seq_len)
        logits, cache, _ = forward_full(params, self.cfg, tokens=tokens,
                                        cache=cache)
        cache["pos"] = jnp.full_like(cache["pos"], length)
        return logits[0, length - 1], cache

    # ------------------------------------------------------- jitted paged
    def _decode_step_paged(self, params, cache, tokens, active):
        """Paged decode round.  KV pages need no inactive-slot freeze:
        freed slots' block tables point at the null page, so their
        (masked) token writes never touch live pages.  Per-slot state
        (pos, SSM conv/ssm) is still frozen."""
        logits, new_cache = forward_decode_paged(
            params, self.cfg, tokens=tokens, cache=new_cache_arg(cache))

        def keep(new, old):
            mask = active.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(mask, new, old)
        layers = []
        for new_l, old_l in zip(new_cache["layers"], cache["layers"]):
            if "conv" in new_l:
                layers.append({k: keep(new_l[k], old_l[k]) for k in new_l})
            else:
                layers.append(new_l)
        return logits[:, -1], {
            "pos": jnp.where(active, new_cache["pos"], cache["pos"]),
            "block_tables": new_cache["block_tables"], "layers": layers}

    def _prefill_paged(self, params, cache, tokens, length, slot):
        """Whole-prompt prefill written in place into ``slot``'s pages."""
        return forward_prefill_paged(params, self.cfg, tokens=tokens,
                                     cache=new_cache_arg(cache), slot=slot,
                                     length=length)

    def _prefill_chunk_paged(self, params, cache, tokens, slot, length):
        """One chunk continuation for ``slot`` against the paged pool.
        ``length`` (traced) marks the valid rows of a padded chunk —
        padded rows route to the null page and are causally masked."""
        return forward_chunk_paged(params, self.cfg, tokens=tokens,
                                   cache=new_cache_arg(cache), slot=slot,
                                   length=length)

    def _warm_paged(self, fn, *args):
        """Compile-warm a donated-cache jitted fn without perturbing
        engine state: snapshot the cache to host, run once, restore."""
        saved = jax.tree.map(np.asarray, self.cache)
        out = fn(self.params, self.cache, *args)
        jax.block_until_ready(out)
        self.cache = self._commit(jax.tree.map(jnp.asarray, saved))

    # --------------------------------------- dispatch/sync split (serving)
    def _slice_slots(self, cache, width):
        """View of the first ``width`` slots of a paged cache: per-slot
        state (pos, block tables, SSM conv/ssm) is sliced, shared page
        arrays pass through untouched."""
        return {
            "pos": cache["pos"][:width],
            "block_tables": cache["block_tables"][:width],
            "layers": [{k: (v[:width] if k in ("conv", "ssm") else v)
                        for k, v in l.items()} for l in cache["layers"]]}

    def _merge_slots(self, cache, sub, width):
        """Scatter a sliced sub-cache back into the full slot pool."""
        layers = []
        for full_l, sub_l in zip(cache["layers"], sub["layers"]):
            layers.append({k: (full_l[k].at[:width].set(sub_l[k])
                               if k in ("conv", "ssm") else sub_l[k])
                           for k in full_l})
        return {"pos": cache["pos"].at[:width].set(sub["pos"]),
                "block_tables": cache["block_tables"], "layers": layers}

    def _decode_dispatch_paged(self, params, cache, tokens, active, key):
        """Fused decode round + on-device sampling over the first
        ``tokens.shape[0]`` slots (a pow-2 batch bucket).  Sampling in
        the same jit means the sampled ids stay on device: the next
        round can be dispatched from them without a host round-trip —
        the core of the serving loop's one-step lookahead."""
        width = tokens.shape[0]
        if width == self.max_slots:
            logits, new_cache = self._decode_step_paged(params, cache,
                                                        tokens, active)
            return sample(logits, key, self.temperature), new_cache
        sub = self._slice_slots(cache, width)
        logits, new_sub = self._decode_step_paged(params, sub, tokens,
                                                  active)
        return (sample(logits, key, self.temperature),
                self._merge_slots(cache, new_sub, width))

    def _decode_dispatch_dense(self, params, cache, tokens, active, key):
        """Dense-layout fused decode+sample (always full slot width)."""
        logits, new_cache = self._decode_step(params, cache, tokens, active)
        return sample(logits, key, self.temperature), new_cache

    def dispatch_decode(self, feed, active_np, width: Optional[int] = None,
                        lookahead: int = 0):
        """Dispatch one fused decode+sample round WITHOUT syncing.

        ``feed``: ``[max_slots, 1]`` int32 device array of input token
        ids (each running slot's last sampled token — typically the
        device output of the previous ``dispatch_decode``, so chained
        rounds never touch the host).  ``active_np``: host bool mask.
        ``width``: static batch width (a pow-2 bucket covering every
        active slot; paged mode only) — smaller widths skip the dead
        tail of the slot pool at one extra compile per bucket.
        ``lookahead``: extra write positions the copy-on-write guard
        must cover when earlier rounds are still in flight.

        Returns the device array of sampled next-token ids (``[width]``)
        immediately; the caller reads it back later (``np.asarray``)
        after doing host-side work — scheduling, stream delivery, block
        accounting — while the device computes.
        """
        B = self.max_slots if (width is None or not self.paged) \
            else int(width)
        if not (0 < B <= self.max_slots):
            raise ValueError(f"width {width} outside (0, {self.max_slots}]")
        if any(active_np[B:]):
            raise ValueError(f"active slot >= dispatch width {B}")
        if self.paged:
            self._cow_guard(lookahead)
        if ("dispatch", B) not in self._warm:
            args = (feed[:B], jnp.zeros(B, bool),
                    jax.random.PRNGKey(0))
            if self.paged:
                self._warm_paged(self._dispatch_fn, *args)
            else:
                jax.block_until_ready(
                    self._dispatch_fn(self.params, self.cache, *args))
            self._warm.add(("dispatch", B))
        self.key, sk = jax.random.split(self.key)
        toks, self.cache = self._dispatch_fn(
            self.params, self.cache, feed[:B],
            jnp.asarray(active_np[:B]), sk)
        return toks

    def finish_slot(self, rt: RuntimeRequest):
        """Release a finished request's slot: publish its KV-valid span
        (prompt + all but the never-written final token) to the prefix
        index, return its blocks, and free the slot.  The caller stamps
        phase/finish_time — the serving loop uses wall-clock stamps, the
        batch loop the engine clock."""
        self._index_span(rt, rt.input_len + len(rt.generated) - 1)
        self._release_blocks(rt.slot)
        self.slot_free[rt.slot] = True
        self.slot_req[rt.slot] = None

    # ------------------------------------------------------------ blocks
    def _blocks_needed(self, rt: RuntimeRequest) -> int:
        """Pages covering the request's lifetime token footprint (prompt
        + output budget, capped by the slot's ring length)."""
        tokens = min(rt.input_len + rt.max_new_tokens, self.slot_len)
        return -(-tokens // self.block_size)

    def _prefix_eligible(self, rt: RuntimeRequest) -> bool:
        """Prefix sharing is safe only while the slot ring never wraps:
        a wrap would overwrite aliased pages in place."""
        return (self.prefix is not None
                and rt.input_len + rt.max_new_tokens <= self.slot_len)

    def _probe_cached(self, rt: RuntimeRequest) -> int:
        """Read-only longest-cached-prefix length (tokens) for pricing."""
        if not self._prefix_eligible(rt):
            return 0
        ctx = self._context_tokens(rt)
        return self.prefix.probe(ctx, max_tokens=len(ctx) - 1)

    def _unique_blocks_needed(self, rt: RuntimeRequest) -> int:
        """Blocks the request needs *beyond* the cached prefix it would
        alias — what admission must actually find in the free list."""
        return self._blocks_needed(rt) \
            - self._probe_cached(rt) // self.block_size

    def _admission_blocks(self) -> int:
        """Blocks admission can draw on: the free list plus cached pages
        only the prefix index holds (evictable on demand)."""
        extra = self.prefix.reclaimable() if self.prefix is not None else 0
        return self.pool.available + extra

    def _reserve_blocks(self, rt: RuntimeRequest) -> bool:
        """Atomically reserve the request's block footprint: alias the
        longest cached block-aligned prefix (sharing those pages), evict
        index-only pages if the free list is short, and allocate the
        rest.  The reservation lands in ``rt.block_ids`` /
        ``rt.cached_tokens`` and is consumed by the next prefill.
        Returns False (no state change) when blocks don't cover it."""
        if rt.block_ids is not None:
            return True                      # already reserved this step
        need = self._blocks_needed(rt)
        matched: List[int] = []
        if self._prefix_eligible(rt):
            ctx = self._context_tokens(rt)
            # cap at len-1: the request always writes at least one new
            # token position, so a full-context hit must still leave the
            # final block's frontier in a page this request owns
            matched = self.prefix.match(ctx, max_tokens=len(ctx) - 1)
            self.pool.share(matched)         # pin before any eviction
        n_new = need - len(matched)
        short = n_new - self.pool.available
        if short > 0 and self.prefix is not None:
            self.prefix.evict(short)
        if n_new > self.pool.available:
            self.pool.release(matched)       # roll back the pin
            return False
        rt.block_ids = matched + self.pool.alloc(n_new)
        rt.cached_tokens = len(matched) * self.block_size
        return True

    def _assign_blocks(self, rt: RuntimeRequest, slot: int):
        # upgrade an admission-time reservation: prefills earlier in the
        # same step may have indexed this prompt's prefix since — a
        # re-reservation shares more and allocates strictly less, so it
        # can never fail where the original succeeded
        if rt.block_ids is not None and self._prefix_eligible(rt):
            ctx = self._context_tokens(rt)
            if self.prefix.probe(ctx, max_tokens=len(ctx) - 1) \
                    > rt.cached_tokens:
                self.pool.release(rt.block_ids)
                rt.block_ids = None
                rt.cached_tokens = 0
        if not self._reserve_blocks(rt):
            raise RuntimeError(
                f"out of KV blocks: request {rt.req_id} needs "
                f"{self._unique_blocks_needed(rt)} new blocks, "
                f"{self.pool.available} free")
        ids = rt.block_ids
        rt.block_ids = None                  # reservation consumed
        self._slot_blocks[slot] = ids
        row = np.zeros(self.pages_per_slot, np.int32)
        row[:len(ids)] = ids
        self.cache["block_tables"] = \
            self.cache["block_tables"].at[slot].set(jnp.asarray(row))

    def _release_blocks(self, slot: int):
        if self.paged and self._slot_blocks[slot]:
            self.pool.release(self._slot_blocks[slot])
            self._slot_blocks[slot] = []
            self.cache["block_tables"] = \
                self.cache["block_tables"].at[slot].set(0)

    def _index_span(self, rt: RuntimeRequest, n_tokens: int):
        """Publish the slot's first ``n_tokens`` KV positions to the
        prefix index (full blocks only; the index takes its own ref on
        each newly indexed page, so they outlive this request)."""
        if rt.slot < 0 or not self._prefix_eligible(rt):
            return
        ctx = self._context_tokens(rt)
        self.prefix.insert(ctx[:n_tokens], self._slot_blocks[rt.slot],
                           max_tokens=n_tokens)

    def _cow_block(self, slot: int, bi: int) -> int:
        """Give ``slot`` a private copy of its ``bi``-th page (copy-on-
        write) if other owners share it.  Returns the (possibly new)
        page id."""
        old = self._slot_blocks[slot][bi]
        if self.pool.refcount(old) <= 1:
            return old
        if not self.pool.available and self.prefix is not None:
            self.prefix.evict(1)
        new = self.pool.alloc(1)[0]
        self.cache = self._commit(copy_page(self.cache, old, new))
        self._slot_blocks[slot][bi] = new
        self.cache["block_tables"] = \
            self.cache["block_tables"].at[slot, bi].set(new)
        self.pool.release([old])
        self.cow_copies += 1
        return new

    def _cow_guard(self, lookahead: int = 0):
        """Before a decode round writes, split any shared page a slot's
        write frontier sits in.  Block-aligned matching (capped below
        the full context) makes this structurally unreachable through
        normal admission — kept as defense in depth so a shared page
        can never be scribbled on.  ``lookahead`` widens the guard to
        the next write positions when earlier decode rounds are still
        in flight (the serving loop's overlapped dispatch): their host
        token counts lag the device by that many rounds."""
        for slot, rt in enumerate(self.slot_req):
            if rt is None:
                continue
            # a slot mid-prefill writes at its chunk frontier, not at
            # the last-generated position
            pos = rt.prefill_pos if rt.phase is Phase.PREFILLING \
                else rt.input_len + len(rt.generated) - 1
            blocks = self._slot_blocks[slot]
            for d in range(lookahead + 1):
                bi = ((pos + d) % self.slot_len) // self.block_size
                if bi < len(blocks) and \
                        self.pool.refcount(blocks[bi]) > 1:
                    self._cow_block(slot, bi)

    # ------------------------------------------------------------ slots
    def _write_slot(self, slot: int, cache1):
        """Copy a single-request cache into slot ``slot`` of the pool."""
        def put(pool, one):
            return pool.at[slot].set(one[0])
        self.cache["layers"] = [
            {k: put(self.cache["layers"][i][k], cache1["layers"][i][k])
             for k in self.cache["layers"][i]}
            for i in range(len(self.cache["layers"]))]
        self.cache["pos"] = self.cache["pos"].at[slot].set(cache1["pos"][0])

    def free_slots(self) -> List[int]:
        return [i for i, f in enumerate(self.slot_free) if f]

    # ------------------------------------------------------------ steps
    def _context_tokens(self, rt: RuntimeRequest) -> np.ndarray:
        """Prefill context: the prompt, plus — after a preemption — the
        tokens already generated (vLLM-style KV recompute)."""
        if not rt.generated:
            return np.asarray(rt.prompt_tokens, np.int32)
        return np.concatenate([np.asarray(rt.prompt_tokens, np.int32),
                               np.asarray(rt.generated, np.int32)])

    def begin_prefill(self, rt: RuntimeRequest, slot: int):
        """Claim ``slot`` for ``rt`` and stage its prefill — blocks are
        assigned (paged; the cached-prefix span is aliased and skipped)
        and the request enters ``Phase.PREFILLING``, but no compute
        runs.  :meth:`prefill_step` then advances the staged span,
        possibly across several ticks (chunk-as-tick): mid-prefill the
        slot is occupied but the request is invisible to decode rounds
        and to the policies' active view."""
        ctx = self._context_tokens(rt)
        n = len(ctx)
        if n >= self.max_seq_len:
            raise ValueError(f"prefill context {n} >= max_seq_len")
        # block assignment is deferred to the first prefill_step: a
        # prefill completing earlier in the same tick indexes its span,
        # and the assignment-time re-probe then aliases this prompt's
        # cached prefix (the admission reservation in rt.block_ids
        # keeps the pages safe meanwhile)
        rt.prefill_pos = 0
        rt.phase = Phase.PREFILLING
        rt.slot = slot
        self.slot_free[slot] = False
        self.slot_req[slot] = rt

    def prefill_step(self, rt: RuntimeRequest,
                     length: Optional[int] = None) -> bool:
        """Advance ``rt``'s staged prefill by ``length`` context tokens
        (the whole remaining span when None) — one timed jit call, one
        profiler sample.  The final span samples the first output token
        (its logits sit at the true last context position) and flips
        the request to RUNNING, so it joins the same tick's decode
        round.  Returns True when the prefill completed."""
        if rt.phase is not Phase.PREFILLING:
            raise ValueError(f"request {rt.req_id} has no staged prefill")
        slot = rt.slot
        if self.paged and not self._slot_blocks[slot]:
            # first step: claim the pages (aliasing any prefix indexed
            # since admission) and skip the cached span — its aliased
            # pages are already populated, so the compute starts
            # mid-sequence
            self._assign_blocks(rt, slot)
            self.cache["pos"] = self.cache["pos"].at[slot].set(
                rt.cached_tokens)
            rt.prefill_pos = rt.cached_tokens
        ctx = self._context_tokens(rt)
        n = len(ctx)
        done = rt.prefill_pos
        m = n - done if length is None else min(int(length), n - done)
        if m <= 0:
            raise ValueError(f"empty prefill span for request {rt.req_id}")
        last = done + m >= n
        whole = done == 0 and last
        cache1 = None
        if whole:
            # whole-context fast path: the bucketed prefill jit.  SSM/
            # hybrid states are sequence-order sensitive, so those archs
            # prefill at exact length (one compile per distinct length).
            L = n if self.cfg.ssm_layers else _bucket(n)
            toks = np.zeros((1, L), np.int32)
            toks[0, :n] = ctx
            toks = jnp.asarray(toks)
            # warm the jit cache per bucket so first-seen compile time
            # never pollutes the engine clock / profiler samples
            if ("prefill", L) not in self._warm:
                if self.paged:
                    self._warm_paged(self._prefill_fn, toks, n, slot)
                else:
                    self._prefill_fn(self.params, toks,
                                     n)[0].block_until_ready()
                self._warm.add(("prefill", L))
            t0 = time.perf_counter()
            if self.paged:
                logits, self.cache = self._prefill_fn(
                    self.params, self.cache, toks, n, slot)
            else:
                logits, cache1 = self._prefill_fn(self.params, toks, n)
            row = logits[None, :]
        elif self.paged:
            # chunk/suffix continuation against the paged pool: padded
            # to a pow-2 bucket with a traced valid length (padded rows
            # route to the null page and are causally masked), so a
            # ragged final chunk reuses the compiled bucket
            L = _bucket(m)
            toks = np.zeros((1, L), np.int32)
            toks[0, :m] = ctx[done:done + m]
            toks = jnp.asarray(toks)
            if ("chunk", L) not in self._warm:
                self._warm_paged(self._chunk_fn, toks, slot, m)
                self._warm.add(("chunk", L))
            t0 = time.perf_counter()
            logits, self.cache = self._chunk_fn(self.params, self.cache,
                                                toks, slot, m)
            row = logits[:, 0]
        else:
            # dense chunk walk over a private single-slot cache (exact
            # length: SSM recurrent state tolerates no pad tokens);
            # committed to the pool only at completion
            if slot not in self._partial:
                self._partial[slot] = init_cache(self.cfg, 1,
                                                 self.max_seq_len)
            cache1 = self._partial[slot]
            toks = jnp.asarray(np.asarray(ctx[done:done + m],
                                          np.int32)[None])
            if ("chunk", m) not in self._warm:
                self._chunk_fn(self.params, cache1,
                               toks)[0].block_until_ready()
                self._warm.add(("chunk", m))
            t0 = time.perf_counter()
            logits, cache1 = self._chunk_fn(self.params, cache1, toks)
            self._partial[slot] = cache1
            row = logits[:, 0]
        row.block_until_ready()
        dt = time.perf_counter() - t0
        self.clock += dt
        if self.profiler is not None:
            # chunk continuations are prefill work: feed them to the
            # latency-model fit like whole-prompt prefills
            self.profiler.observe_prefill(1, m, dt)
        rt.prefill_pos = done + m
        if not last:
            return False
        if not self.paged:
            self._write_slot(slot, cache1 if whole
                             else self._partial.pop(slot))
        rt.phase = Phase.RUNNING
        self._index_span(rt, n)
        if rt.ttft_time is None:            # preserved across preemptions
            rt.ttft_time = self.clock
        self.key, sk = jax.random.split(self.key)
        tok = int(sample(row, sk, self.temperature)[0])
        self._push_token(rt, tok)
        return True

    def prefill(self, rt: RuntimeRequest, slot: int):
        """Whole-prompt prefill: stage the slot and compute the full
        remaining context in one step (any cached prefix aliased).  The
        plan-driven executors instead call :meth:`begin_prefill` once
        and :meth:`prefill_step` per tick, as the discipline's
        :class:`~repro.core.policies.StepPlan` dictates."""
        self.begin_prefill(rt, slot)
        self.prefill_step(rt)

    def preempt(self, rt: RuntimeRequest):
        """Evict a running request: free its slot and discard its KV
        (paged: its blocks return to the pool immediately).  The
        generated tokens and TTFT are kept; the next prefill of this
        request recomputes prompt + generated (cost charged as a normal
        prefill)."""
        if rt.slot < 0 or self.slot_req[rt.slot] is not rt:
            raise ValueError(f"request {rt.req_id} is not running")
        self._partial.pop(rt.slot, None)     # drop any half-built cache
        self._release_blocks(rt.slot)
        self.slot_free[rt.slot] = True
        self.slot_req[rt.slot] = None
        rt.slot = -1
        rt.phase = Phase.WAITING
        rt.prefill_pos = 0
        rt.preemptions += 1

    def _push_token(self, rt: RuntimeRequest, tok: int):
        rt.generated.append(tok)
        if (self.eos >= 0 and tok == self.eos) or \
                len(rt.generated) >= rt.max_new_tokens:
            rt.phase = Phase.FINISHED
            rt.finish_time = self.clock
            self.finish_slot(rt)

    def decode_round(self):
        """One decode iteration over every RUNNING slot.  Slots mid-
        prefill (``Phase.PREFILLING``) are masked out of the batch: in
        paged mode the unmasked page write lands one garbage token at
        their frontier position, which the next prefill chunk overwrites
        before anything reads it (per-slot pos/SSM state *is* frozen by
        the mask)."""
        running = [rt for rt in self.slot_req
                   if rt is not None and rt.phase is Phase.RUNNING]
        active_np = np.array([rt is not None and rt.phase is Phase.RUNNING
                              for rt in self.slot_req])
        if not active_np.any():
            return
        if self.paged:
            self._cow_guard()
        tokens = np.zeros((self.max_slots, 1), np.int32)
        for i, rt in enumerate(self.slot_req):
            if rt is not None and rt.phase is Phase.RUNNING:
                tokens[i, 0] = rt.generated[-1]
        b = int(active_np.sum())
        accum = int(np.max([rt.input_len + len(rt.generated)
                            for rt in running]))
        if "decode" not in self._warm:
            if self.paged:
                self._warm_paged(self._decode_fn, jnp.asarray(tokens),
                                 jnp.asarray(active_np))
            else:
                self._decode_fn(self.params, self.cache, jnp.asarray(tokens),
                                jnp.asarray(active_np))[0].block_until_ready()
            self._warm.add("decode")
        t0 = time.perf_counter()
        logits, self.cache = self._decode_fn(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(active_np))
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        self.clock += dt
        if self.profiler is not None:
            self.profiler.observe_decode(b, accum, dt)
        self.key, sk = jax.random.split(self.key)
        toks = np.asarray(sample(logits, sk, self.temperature))
        for i, rt in enumerate(list(self.slot_req)):
            if rt is not None and rt.phase is Phase.RUNNING:
                self._push_token(rt, int(toks[i]))

    # ------------------------------------------------------- step planner
    def plan_step(self, disc: ExecutionDiscipline) -> StepPlan:
        """Build this tick's :class:`StepPlan` from the slot state: one
        prefill item per PREFILLING slot (span sized by the discipline's
        chunk size; ``ref`` is the slot id) and one decode item per
        RUNNING slot."""
        prefills, decodes = [], []
        for slot, rt in enumerate(self.slot_req):
            if rt is None:
                continue
            if rt.phase is Phase.PREFILLING:
                prefills.append((slot, rt.prefill_pos,
                                 rt.input_len + len(rt.generated)))
            elif rt.phase is Phase.RUNNING:
                decodes.append(slot)
        return disc.plan_step(prefills, decodes)

    def execute_step(self, plan: StepPlan):
        """Execute one mixed tick: advance every planned prefill span,
        then run a single decode round over the RUNNING slots — a
        request whose final chunk just completed is activated before
        the round, so its first decode token rides in the same tick
        (chunk-as-tick)."""
        for it in plan.prefills:
            rt = self.slot_req[it.ref]
            if rt is not None and rt.phase is Phase.PREFILLING:
                self.prefill_step(rt, it.length)
        self.decode_round()

    # ------------------------------------------------------------ views
    def active_requests(self) -> List[RuntimeRequest]:
        """RUNNING requests in slot order — the ordering every
        :class:`SchedulerView` built from this engine uses for its
        ``active`` tuple (so ``Decision.preempt`` indices resolve).
        Slots mid-prefill are excluded: they hold no sampled token yet
        and cannot be decoded or preempted."""
        return [rt for rt in self.slot_req
                if rt is not None and rt.phase is Phase.RUNNING]

    def build_view(self, waiting: Sequence[RuntimeRequest],
                   disc: Optional[ExecutionDiscipline],
                   model: Optional[LinearLatencyModel]) -> SchedulerView:
        """:class:`SchedulerView` over the engine's in-flight state plus
        a waiting list — shared by the batch loop (``run_policy``) and
        the streaming serving loop, so policies see identical views in
        both regimes.  ``now`` is the engine clock (the serving loop
        syncs it to the wall clock each tick)."""
        active_rts = self.active_requests()
        b = max(len(active_rts), 1)
        return SchedulerView(
            pending=tuple(rt.request for rt in waiting),
            active=tuple(make_active_view(
                rt.request, len(rt.generated),
                rt.max_new_tokens - len(rt.generated),
                rt.input_len + len(rt.generated), self.clock,
                rt.ttft_time, rt.submit_time, b, model,
                # only pages this request exclusively owns are freeable
                # by preempting it — shared/indexed pages survive its
                # eviction
                blocks_held=(sum(
                    1 for bl in self._slot_blocks[rt.slot]
                    if self.pool.refcount(bl) == 1)
                    if self.paged else 0))
                for rt in active_rts),
            now=self.clock, free=len(self.free_slots()),
            max_batch=self.max_slots,
            pending_generated=tuple(len(rt.generated) for rt in waiting),
            pending_cached=(tuple(self._probe_cached(rt)
                                  for rt in waiting)
                            if self.paged else ()),
            discipline=disc,
            free_blocks=(self._admission_blocks() if self.paged else None),
            total_blocks=(self.pool.total if self.paged else None),
            block_size=(self.block_size if self.paged else 0),
            pages_per_slot=(self.pages_per_slot if self.paged else 0))

    # ------------------------------------------------------------ runs
    def run_policy(self, rts: Sequence[RuntimeRequest],
                   policy: SchedulingPolicy, *,
                   discipline: "ExecutionDiscipline | str | None" = None,
                   model: Optional[LinearLatencyModel] = None,
                   respect_arrivals: bool = False):
        """Continuous batching with a pluggable :class:`SchedulingPolicy`
        — the *same* policy and :class:`ExecutionDiscipline` objects that
        drive the discrete-event core (``repro.core.events.simulate``),
        so simulated and real runs share one scheduling brain.

        The policy sees a :class:`SchedulerView` (pending + active sets,
        slack under ``model`` when provided) and may *preempt* running
        requests; evicted requests lose their KV and are re-prefilled on
        re-admission (prompt + generated tokens).  ``discipline``
        overrides the engine's prefill mode for this run
        (``StallingPrefill`` / ``ChunkedPrefill(n)`` / registry key);
        when omitted, a policy that carries its own discipline
        (dynamic-chunk's ``AdaptiveChunkedPrefill``) runs under it, else
        the engine's ``chunked_prefill`` default applies.  The chosen
        discipline drives the per-tick :class:`StepPlan` — engine config
        is never mutated, so a policy that raises mid-run cannot leave
        the engine reconfigured.  ``respect_arrivals=True`` releases
        each request into the waiting queue only once
        ``Request.arrival_time`` (relative to the run start) has passed
        on the engine clock.
        """
        pol, preemptive = resolve_policy(policy, model=model,
                                         max_batch=self.max_slots)
        if model is None:
            # model-driven policies (slo-reanneal, slo-preempt) carry the
            # latency model the slack projections in the views need
            model = getattr(pol, "model", None)
        if discipline is None:
            # adopt the policy's own discipline: adaptive disciplines
            # (AdaptiveChunkedPrefill) are mutated by their policy
            # mid-run and the planner re-reads chunk_size every tick,
            # so object identity matters (make_discipline passes
            # instances through untouched)
            discipline = getattr(pol, "discipline", None)
        if discipline is not None:
            disc = make_discipline(discipline)
        else:
            disc = ChunkedPrefill(self.chunked_prefill) \
                if self.chunked_prefill else StallingPrefill()
        if disc.chunk_size and self.cfg.mla is not None:
            # MLA archs have no chunked path (see __init__)
            warnings.warn(
                f"{disc!r} is unsupported for MLA archs; falling "
                "back to whole-prompt (stalling) prefill")
            disc = StallingPrefill()
        return self._run_policy_loop(rts, pol, preemptive, model,
                                     respect_arrivals, disc)

    def _run_policy_loop(self, rts, pol, preemptive, model,
                         respect_arrivals, disc):
        rts = list(rts)
        t0 = self.clock
        if respect_arrivals:
            future = sorted(rts, key=lambda rt: rt.request.arrival_time)
            waiting: List[RuntimeRequest] = []
        else:
            future, waiting = [], list(rts)
            for rt in waiting:
                rt.submit_time = self.clock
                rt.request.submit_time = self.clock
        fi = 0
        while waiting or fi < len(future) or not all(self.slot_free):
            # compare on t0 + arrival (not arrival <= clock - t0): the
            # idle-wait below advances the clock to exactly t0 + arrival,
            # and (t0 + a) - t0 can round *below* a, which would leave
            # the request unpulled and the clock pinned — a livelock
            while fi < len(future) and \
                    t0 + future[fi].request.arrival_time <= self.clock:
                rt = future[fi]
                # the true arrival instant (<= self.clock): queueing delay
                # accrued while the engine was mid-step must count toward
                # e2e/TTFT and SLO-budget shifting, as in the event core
                rt.submit_time = t0 + rt.request.arrival_time
                rt.request.submit_time = rt.submit_time
                waiting.append(rt)
                fi += 1
            free = self.free_slots()
            admitted = False
            decided = False
            if waiting and (free or (preemptive
                                     and not all(self.slot_free))):
                view = self.build_view(waiting, disc, model)
                # adaptive disciplines rewrite chunk_size inside
                # decide(); this tick's plan runs under the new size
                admit, preempt = normalize_decision(pol.decide(view), view)
                decided = True
                active_rts = self.active_requests()
                for j in preempt:
                    vict = active_rts[j]
                    # re-prefill must fit: prompt + generated + next token
                    if vict.input_len + len(vict.generated) + 1 \
                            >= self.max_seq_len:
                        continue
                    self.preempt(vict)
                    waiting.append(vict)        # view indices stay valid
                    admitted = True
                free = self.free_slots()
                sel = []
                for j in admit:
                    if len(sel) >= len(free):
                        break
                    # reserve atomically (alias cached prefix + alloc the
                    # unique rest) so same-step admissions never race a
                    # probe against a later allocation
                    if self.paged and not self._reserve_blocks(waiting[j]):
                        continue        # out of KV blocks: keep waiting
                    sel.append(j)
                chosen = [waiting[j] for j in sel]
                for j in sorted(sel, reverse=True):
                    waiting.pop(j)
                for rt, slot in zip(chosen, free):
                    # stage only: the prefill advances through the tick
                    # plans below, chunked or whole per the discipline
                    self.begin_prefill(rt, slot)
                admitted = admitted or bool(chosen)
            retune = getattr(pol, "retune", None)
            if not decided and retune is not None \
                    and not all(self.slot_free):
                # decide() didn't run this tick (empty queue): let an
                # adaptive policy keep resizing its chunk against the
                # current active set, as the event core does
                retune(self.build_view([], disc, model))
            idle = all(self.slot_free)
            self.execute_step(self.plan_step(disc))
            if idle and not admitted:
                if fi < len(future):
                    # idle-wait for the next arrival on the engine clock
                    self.clock = max(self.clock,
                                     t0 + future[fi].request.arrival_time)
                elif waiting:
                    if self.paged and all(
                            self._unique_blocks_needed(rt)
                            > self._admission_blocks()
                            for rt in waiting):
                        rt = waiting[0]
                        raise ValueError(
                            f"request {rt.req_id} needs "
                            f"{self._unique_blocks_needed(rt)} KV blocks "
                            f"but only {self._admission_blocks()} exist: "
                            "prompt + output budget exceeds the block "
                            "pool")
                    raise RuntimeError("admission stalled: policy admitted "
                                       "nothing while the engine was idle")
        return self._collect(rts)

    def run_fcfs(self, rts: Sequence[RuntimeRequest], **kw):
        """Continuous batching, FCFS admission."""
        return self.run_policy(rts, FCFSPolicy(), **kw)

    def run_priority(self, batches: Sequence[Sequence[RuntimeRequest]],
                     **kw):
        """Continuous batching with the planned priority order as arrival
        order — the paper's actual dispatch (§5.1: batches submitted 0.1 ms
        apart into a continuously-batching engine)."""
        return self.run_policy([rt for b in batches for rt in b],
                               FCFSPolicy(), **kw)

    def run_planned(self, batches: Sequence[Sequence[RuntimeRequest]],
                    **kw):
        """Execute scheduler-planned batches sequentially (barrier between
        batches, enforced by ``PlannedPolicy``)."""
        allr = [rt for b in batches for rt in b]
        return self.run_policy(allr, PlannedPolicy(batches), **kw)

    def _collect(self, rts):
        out = {}
        for rt in rts:
            e2e, ttft, tpot = rt.metrics()
            out[rt.req_id] = {
                "e2e": e2e, "ttft": ttft, "tpot": tpot,
                "tokens": list(rt.generated),
                "met": meets_slo(rt.request, e2e, ttft, tpot),
                "preemptions": rt.preemptions,
                "cached": rt.cached_tokens,
            }
        return out

    def prefix_stats(self) -> Dict[str, float]:
        """Prefix-cache counters for benchmarks/diagnostics."""
        if self.prefix is None:
            return {"hit_rate": 0.0, "cached_blocks": 0, "cow_copies": 0,
                    "enabled": False}
        return {"hit_rate": self.prefix.hit_rate,
                "cached_blocks": len(self.prefix),
                "cow_copies": self.cow_copies, "enabled": True}


def new_cache_arg(cache):
    """Shallow rebuild so jit donation aliasing never mutates caller state."""
    out = {"pos": cache["pos"],
           "layers": [dict(l) for l in cache["layers"]]}
    if "block_tables" in cache:
        out["block_tables"] = cache["block_tables"]
    return out
