"""Runtime request state machine for the serving engine."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

import numpy as np

from repro.core.slo import SLO, Request


class Phase(enum.Enum):
    WAITING = 0
    RUNNING = 1
    FINISHED = 2
    # holds a slot and KV blocks, but its prefill is still advancing
    # chunk-by-chunk across ticks (no sampled token yet) — excluded
    # from decode rounds and from the policies' active view
    PREFILLING = 3


@dataclasses.dataclass
class RuntimeRequest:
    """A request being executed by the engine."""
    request: Request
    prompt_tokens: np.ndarray            # [l_in] int32
    max_new_tokens: int
    phase: Phase = Phase.WAITING
    slot: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    submit_time: float = 0.0
    ttft_time: Optional[float] = None
    finish_time: Optional[float] = None
    preemptions: int = 0                 # times evicted (KV recomputed)
    # prompt tokens served from the prefix cache at the last prefill
    # (aliased pages — skipped, not computed); 0 without a prefix hit
    cached_tokens: int = 0
    # block reservation made at admission, consumed by the next prefill
    # (engine-internal; None outside the admit -> prefill window)
    block_ids: Optional[List[int]] = None
    # context positions already computed of an in-progress prefill
    # (starts at the cached-prefix length; meaningful while PREFILLING)
    prefill_pos: int = 0

    @property
    def req_id(self) -> int:
        return self.request.req_id

    @property
    def input_len(self) -> int:
        return len(self.prompt_tokens)

    def metrics(self):
        """(e2e, ttft, tpot) in seconds relative to submit."""
        e2e = (self.finish_time or 0.0) - self.submit_time
        ttft = (self.ttft_time or 0.0) - self.submit_time
        ngen = max(len(self.generated), 1)
        tpot = (e2e - ttft) / ngen
        return e2e, ttft, tpot
