"""Radix prefix index over KV blocks (vLLM/SGLang-style prefix caching).

Maps token-id prefixes to cached KV pages at **block granularity**: a
trie node is one full block of ``block_size`` token ids (its key), and
holds the page id whose KV was computed for exactly those tokens at
those positions.  An arriving prompt walks the trie block by block;
every hit is a page the request can alias instead of re-prefilling
(:meth:`match` → ``BlockPool.share``), so prefill starts mid-sequence
and the scheduler prices only the *unique new* tokens.

The index is itself an owner of every cached block (it calls
``pool.share`` on insert and ``pool.release`` on evict), so cached
pages outlive the request that produced them: a sharer's preemption or
finish releases *its* reference, never the index's.  Blocks whose only
remaining owner is the index (refcount 1) are **reclaimable** — the
engine counts them as available to admission and evicts them LRU-wise
(leaves first, so the trie never orphans a descendant) when the free
list runs short.

Only full blocks are ever indexed, and matches are capped below the
prompt length (at least one token is always computed, so prefill
produces true last-token logits); divergent writes therefore land in
freshly allocated blocks and copy-on-write is a guard, not a hot path.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple


class _Node:
    __slots__ = ("key", "block", "children", "parent", "tick")

    def __init__(self, key: Optional[Tuple[int, ...]], block: int,
                 parent: Optional["_Node"]):
        self.key = key                    # block_size token ids (None: root)
        self.block = block                # page id (-1: root)
        self.children: Dict[Tuple[int, ...], _Node] = {}
        self.parent = parent
        self.tick = 0                     # LRU stamp (match/insert touch)


class RadixPrefixIndex:
    """Block-granular trie from token-id prefixes to cached page ids."""

    def __init__(self, pool, block_size: int):
        self.pool = pool
        self.block_size = int(block_size)
        self._root = _Node(None, -1, None)
        self._nodes = 0                   # cached blocks (excl. root)
        self._tick = 0
        # token-level counters for hit-rate reporting
        self.hit_tokens = 0
        self.lookup_tokens = 0

    def __len__(self) -> int:
        return self._nodes

    # ------------------------------------------------------------ lookup
    def _keys(self, tokens: Sequence[int], max_tokens: Optional[int]
              ) -> List[Tuple[int, ...]]:
        n = len(tokens)
        if max_tokens is not None:
            n = min(n, int(max_tokens))
        P = self.block_size
        return [tuple(int(t) for t in tokens[i:i + P])
                for i in range(0, n - P + 1, P)]

    def _walk(self, tokens: Sequence[int], max_tokens: Optional[int]
              ) -> List[_Node]:
        node, out = self._root, []
        for key in self._keys(tokens, max_tokens):
            nxt = node.children.get(key)
            if nxt is None:
                break
            out.append(nxt)
            node = nxt
        return out

    def probe(self, tokens: Sequence[int],
              max_tokens: Optional[int] = None) -> int:
        """Longest cached block-aligned prefix of ``tokens`` (limited to
        the first ``max_tokens``), in tokens.  Read-only: no LRU touch,
        no refcount change — admission pricing uses this."""
        return len(self._walk(tokens, max_tokens)) * self.block_size

    def match(self, tokens: Sequence[int],
              max_tokens: Optional[int] = None) -> List[int]:
        """Page ids of the longest cached block-aligned prefix.  Touches
        the path for LRU.  The caller owns sharing: ``pool.share`` the
        returned ids *before* anything can evict them."""
        path = self._walk(tokens, max_tokens)
        self._tick += 1
        for nd in path:
            nd.tick = self._tick
        n = len(tokens) if max_tokens is None \
            else min(len(tokens), int(max_tokens))
        self.lookup_tokens += max(n, 0)
        self.hit_tokens += len(path) * self.block_size
        return [nd.block for nd in path]

    # ------------------------------------------------------------ insert
    def insert(self, tokens: Sequence[int], block_ids: Sequence[int],
               max_tokens: Optional[int] = None) -> int:
        """Index ``tokens``' full blocks, backed by ``block_ids`` (the
        owner's pages, position-aligned: ``block_ids[d]`` holds tokens
        ``[d*P, (d+1)*P)``).  Each newly indexed block gains the index
        as an owner (``pool.share``); blocks whose key is already cached
        keep the existing page (same content — keys *are* the content),
        and the offered duplicate stays solely with the caller.  Returns
        the number of blocks newly indexed."""
        node, new = self._root, 0
        self._tick += 1
        for d, key in enumerate(self._keys(tokens, max_tokens)):
            nxt = node.children.get(key)
            if nxt is None:
                if d >= len(block_ids):
                    break
                self.pool.share([block_ids[d]])
                nxt = _Node(key, block_ids[d], node)
                node.children[key] = nxt
                self._nodes += 1
                new += 1
            nxt.tick = self._tick
            node = nxt
        return new

    # ------------------------------------------------------------ evict
    def reclaimable(self) -> int:
        """Cached blocks no request currently aliases (refcount 1: the
        index is the only owner) — memory admission may count these as
        free, since :meth:`evict` can hand them back."""
        return sum(1 for nd in self._iter() if self.pool.refcount(nd.block) == 1)

    def _iter(self):
        stack = list(self._root.children.values())
        while stack:
            nd = stack.pop()
            yield nd
            stack.extend(nd.children.values())

    def evict(self, need: int) -> int:
        """Release up to ``need`` reclaimable blocks, least recently used
        leaves first (a freed leaf may expose its parent next, so deep
        cold chains unwind).  Blocks any request still aliases
        (refcount > 1) are never touched.  Returns the number evicted."""
        freed = 0
        while freed < need:
            victim = None
            for nd in self._iter():
                if nd.children:
                    continue
                if self.pool.refcount(nd.block) != 1:
                    continue
                if victim is None or nd.tick < victim.tick:
                    victim = nd
            if victim is None:
                break
            del victim.parent.children[victim.key]
            self._nodes -= 1
            self.pool.release([victim.block])
            freed += 1
        return freed

    def clear(self) -> None:
        """Release every cached block and reset the trie (pool drain)."""
        for nd in self._iter():
            self.pool.release([nd.block])
        self._root = _Node(None, -1, None)
        self._nodes = 0

    @property
    def hit_rate(self) -> float:
        """Token-level prefix hit rate over all :meth:`match` calls."""
        return self.hit_tokens / self.lookup_tokens \
            if self.lookup_tokens else 0.0
