"""Trace-replay workloads: paper-dataset length distributions + arrival
processes.

The paper evaluates on two public datasets this container cannot
download:

  * Python-Code-23k-ShareGPT  [hf:ajibawa-2023/Python-Code-23k-ShareGPT]
      code generation — e2e SLO (30 s)
  * ShareGPT_Vicuna_unfiltered [hf:anon8231489123/ShareGPT_Vicuna_unfiltered]
      chat — TTFT (10 s) + TPOT (50 ms) SLOs

Instead of parametric stand-ins (``repro.data.synthetic`` fits
lognormals), this module replays *length histograms* checked into
``experiments/traces/*.json`` — inverse-CDF sampling reproduces the
full shape (multi-modal bulk + heavy tail), and swapping the JSON for
one distilled from the real dataset changes nothing downstream.  See
docs/evaluation.md for the file format and how to regenerate.

Arrivals come from three processes (all seeded, all mean-``rate``):

  * ``poisson`` — i.i.d. exponential gaps (the classic open-loop model)
  * ``bursty``  — 2-state MMPP: calm/burst states with a ``burst``-fold
    rate ratio, switching with geometric dwell times
  * ``diurnal`` — inhomogeneous Poisson by thinning against
    ``λ(t) = rate·(1 + depth·sin(2πt/period))``

Every generator funnels into the one shared convention the executors
already speak: :func:`sample_trace` returns ``List[Request]`` (for
``events.simulate`` and the planners) and :func:`sample_trace_workload`
returns ``[(Request, prompt_tokens)]`` (for ``Engine.run_policy`` /
``ServeLoop.submit_trace``), with identical length/arrival draws for a
given seed.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.slo import SLO, Request

TRACES_DIR = pathlib.Path(__file__).resolve().parents[3] \
    / "experiments" / "traces"

#: trace profiles shipped with the repo (experiments/traces/<name>.json)
BUILTIN_TRACES = ("python-code-23k-sharegpt", "sharegpt-vicuna")


# ------------------------------------------------------------- histograms
@dataclasses.dataclass(frozen=True)
class LengthHistogram:
    """A token-length distribution as ``k+1`` ascending bin edges and
    ``k`` non-negative masses.  Sampling is inverse-CDF: pick a bin by
    mass, then uniform within it — reproducing the checked-in shape
    without carrying the raw dataset."""
    edges: Tuple[float, ...]
    counts: Tuple[float, ...]

    def __post_init__(self):
        if len(self.edges) != len(self.counts) + 1:
            raise ValueError("need len(edges) == len(counts) + 1")
        if any(b <= a for a, b in zip(self.edges, self.edges[1:])):
            raise ValueError("edges must be strictly ascending")
        if min(self.counts, default=0.0) < 0 or sum(self.counts) <= 0:
            raise ValueError("counts must be non-negative with mass > 0")

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """``n`` integer lengths (≥ 1) drawn from the histogram."""
        p = np.asarray(self.counts, np.float64)
        p = p / p.sum()
        bins = rng.choice(len(p), size=n, p=p)
        lo = np.asarray(self.edges[:-1], np.float64)[bins]
        hi = np.asarray(self.edges[1:], np.float64)[bins]
        vals = lo + rng.random(n) * (hi - lo)
        return np.maximum(vals.astype(np.int64), 1)

    @classmethod
    def from_samples(cls, values: Sequence[float],
                     bins: int = 32) -> "LengthHistogram":
        """Distill raw lengths (e.g. a real dataset's token counts) into
        a checked-in histogram: log-spaced bins cover the heavy tail."""
        v = np.asarray(values, np.float64)
        v = v[v > 0]
        edges = np.geomspace(v.min(), v.max() + 1.0, bins + 1)
        counts, edges = np.histogram(v, bins=edges)
        return cls(edges=tuple(float(e) for e in edges),
                   counts=tuple(float(c) for c in counts))


@dataclasses.dataclass(frozen=True)
class TraceProfile:
    """One dataset's shape: length histograms + task type + SLO."""
    name: str
    task_type: str
    slo: SLO
    input: LengthHistogram
    output: LengthHistogram
    source: str = ""

    def to_json(self) -> dict:
        return {
            "name": self.name, "task_type": self.task_type,
            "source": self.source,
            "slo": {"ttft": self.slo.ttft, "tpot": self.slo.tpot,
                    "e2e": self.slo.e2e},
            "input": {"edges": list(self.input.edges),
                      "counts": list(self.input.counts)},
            "output": {"edges": list(self.output.edges),
                       "counts": list(self.output.counts)},
        }

    @classmethod
    def from_json(cls, obj: dict) -> "TraceProfile":
        slo = obj.get("slo") or {}
        return cls(
            name=obj["name"], task_type=obj.get("task_type", obj["name"]),
            source=obj.get("source", ""),
            slo=SLO(ttft=slo.get("ttft"), tpot=slo.get("tpot"),
                    e2e=slo.get("e2e")),
            input=LengthHistogram(tuple(obj["input"]["edges"]),
                                  tuple(obj["input"]["counts"])),
            output=LengthHistogram(tuple(obj["output"]["edges"]),
                                   tuple(obj["output"]["counts"])))


def load_trace_profile(name: Union[str, pathlib.Path,
                                   TraceProfile]) -> TraceProfile:
    """Resolve a profile: pass-through, a path to a JSON file, or the
    name of a checked-in trace (``experiments/traces/<name>.json``)."""
    if isinstance(name, TraceProfile):
        return name
    path = pathlib.Path(name)
    if not path.suffix:
        path = TRACES_DIR / f"{name}.json"
    if not path.exists():
        raise FileNotFoundError(
            f"no trace profile {str(name)!r}; built-ins: "
            f"{sorted(BUILTIN_TRACES)} (dir: {TRACES_DIR})")
    with open(path) as f:
        return TraceProfile.from_json(json.load(f))


# --------------------------------------------------------------- arrivals
def poisson_arrivals(n: int, rate: float,
                     rng: np.random.Generator) -> np.ndarray:
    """Homogeneous Poisson process: i.i.d. exponential gaps."""
    if rate <= 0:
        return np.zeros(n)
    return np.cumsum(rng.exponential(1.0 / rate, n))


def bursty_arrivals(n: int, rate: float, rng: np.random.Generator,
                    burst: float = 8.0, burst_frac: float = 0.15,
                    dwell: int = 16) -> np.ndarray:
    """2-state Markov-modulated Poisson process with mean rate ``rate``.

    A fraction ``burst_frac`` of arrivals lands in the burst state,
    where the instantaneous rate is ``burst``× the calm rate; states
    persist for geometric dwells of mean ``dwell`` arrivals.  With
    ``f`` of the arrivals bursty, the long-run rate is
    ``1 / ((1-f)/r_calm + f/(burst·r_calm))``; solving for ``r_calm``
    keeps it equal to the Poisson process at the same ``rate``, so
    attainment curves across processes are load-comparable.
    """
    if rate <= 0:
        return np.zeros(n)
    r_calm = rate * ((1.0 - burst_frac) + burst_frac / burst)
    rates = (r_calm, r_calm * burst)
    # stationary split of *arrivals*: burst_frac of them come from the
    # burst state, so dwell lengths are scaled per state
    dwells = (max(dwell * (1 - burst_frac) / max(burst_frac, 1e-9), 1.0),
              float(max(dwell, 1)))
    state = 1 if rng.random() < burst_frac else 0
    gaps = np.empty(n)
    for i in range(n):
        gaps[i] = rng.exponential(1.0 / rates[state])
        if rng.random() < 1.0 / dwells[state]:
            state = 1 - state
    return np.cumsum(gaps)


def diurnal_arrivals(n: int, rate: float, rng: np.random.Generator,
                     period: float = 300.0,
                     depth: float = 0.8) -> np.ndarray:
    """Inhomogeneous Poisson by thinning: ``λ(t) = rate·(1 +
    depth·sin(2πt/period))`` — a compressed day/night load cycle.
    ``depth`` ∈ [0, 1): 0 degrades to plain Poisson."""
    if rate <= 0:
        return np.zeros(n)
    if not 0.0 <= depth < 1.0:
        raise ValueError("depth must be in [0, 1)")
    lam_max = rate * (1.0 + depth)
    out = np.empty(n)
    t = 0.0
    for i in range(n):
        while True:
            t += rng.exponential(1.0 / lam_max)
            lam = rate * (1.0 + depth * np.sin(2 * np.pi * t / period))
            if rng.random() * lam_max <= lam:
                break
        out[i] = t
    return out


ARRIVAL_PROCESSES = {
    "poisson": poisson_arrivals,
    "bursty": bursty_arrivals,
    "diurnal": diurnal_arrivals,
}


def make_arrivals(n: int, rate: float, process: str = "poisson",
                  rng: Optional[np.random.Generator] = None, seed: int = 0,
                  **kw) -> np.ndarray:
    """Arrival clock for ``n`` requests at mean ``rate`` req/s under a
    named process (``rate <= 0``: everything arrives at t=0)."""
    if process not in ARRIVAL_PROCESSES:
        raise ValueError(f"unknown arrival process {process!r}; one of "
                         f"{sorted(ARRIVAL_PROCESSES)}")
    if rng is None:
        rng = np.random.default_rng(seed)
    return ARRIVAL_PROCESSES[process](n, rate, rng, **kw)


# ----------------------------------------------------------------- traces
def _scale_slo(slo: SLO, scale: float) -> SLO:
    if scale == 1.0:
        return slo
    return SLO(
        ttft=None if slo.ttft is None else slo.ttft * scale,
        tpot=None if slo.tpot is None else slo.tpot * scale,
        e2e=None if slo.e2e is None else slo.e2e * scale)


def sample_trace(n: int, profiles=None, mix: Optional[Sequence[float]] = None,
                 *, rate: float = 0.0, process: str = "poisson",
                 seed: int = 0, slo_scale: float = 1.0,
                 max_input: Optional[int] = None,
                 max_output: Optional[int] = None,
                 arrival_kw: Optional[dict] = None) -> List[Request]:
    """Replay ``n`` requests shaped like the checked-in traces.

    ``profiles`` are :class:`TraceProfile` objects or names (default:
    both paper datasets, evenly mixed per ``mix``); lengths come from
    their histograms, SLOs from their tags (scaled by ``slo_scale`` —
    tiny test engines need proportionally tighter deadlines), arrivals
    from ``process`` at mean ``rate``.  ``max_input``/``max_output``
    clip lengths for small-context executors.  Deterministic in
    ``seed``: requests come back sorted by arrival with contiguous ids.
    """
    profs = [load_trace_profile(p)
             for p in (profiles or BUILTIN_TRACES)]
    p_mix = np.asarray(mix if mix is not None
                       else [1.0 / len(profs)] * len(profs), np.float64)
    if len(p_mix) != len(profs) or p_mix.sum() <= 0:
        raise ValueError("mix must give a positive mass per profile")
    p_mix = p_mix / p_mix.sum()
    rng = np.random.default_rng(seed)
    which = rng.choice(len(profs), size=n, p=p_mix)
    arrivals = make_arrivals(n, rate, process, rng=rng,
                             **(arrival_kw or {}))
    lins = np.stack([p.input.sample(rng, n) for p in profs])
    louts = np.stack([p.output.sample(rng, n) for p in profs])
    reqs = []
    for i in range(n):
        prof = profs[which[i]]
        lin = int(lins[which[i], i])
        lout = int(louts[which[i], i])
        if max_input is not None:
            lin = min(lin, max_input)
        if max_output is not None:
            lout = min(lout, max_output)
        reqs.append(Request(
            req_id=i, task_type=prof.task_type, input_len=max(lin, 1),
            output_len=max(lout, 1), slo=_scale_slo(prof.slo, slo_scale),
            arrival_time=float(arrivals[i])))
    return reqs


def sample_trace_workload(n: int, vocab: int, profiles=None,
                          mix: Optional[Sequence[float]] = None, *,
                          rate: float = 0.0, process: str = "poisson",
                          seed: int = 0, slo_scale: float = 1.0,
                          max_input: Optional[int] = None,
                          max_output: Optional[int] = None,
                          arrival_kw: Optional[dict] = None):
    """Token-level twin of :func:`sample_trace` for engine-backed runs:
    ``[(Request, prompt_tokens)]`` — the convention
    ``Engine.run_policy`` (via ``RuntimeRequest``) and
    ``ServeLoop.submit_trace`` consume.  The request stream is
    *identical* to ``sample_trace(...)`` at the same seed; prompt token
    ids are drawn afterwards so they never perturb the shared draws.
    """
    reqs = sample_trace(n, profiles, mix, rate=rate, process=process,
                        seed=seed, slo_scale=slo_scale,
                        max_input=max_input, max_output=max_output,
                        arrival_kw=arrival_kw)
    tok_rng = np.random.default_rng(np.random.SeedSequence([seed, 1]))
    return [(r, tok_rng.integers(0, vocab, r.input_len).astype(np.int32))
            for r in reqs]
