"""Synthetic ShareGPT-style workloads.

The paper mixes two datasets with distinct task domains and SLO kinds:
  * Python-Code-23k-ShareGPT  [hf:ajibawa-2023/Python-Code-23k-ShareGPT]
      code generation — e2e-latency SLO (h=1).  SLO: 30 s (10× the ~3 s
      single-request time, per §5.1).
  * ShareGPT_Vicuna_unfiltered [hf:anon8231489123/ShareGPT_Vicuna_unfiltered]
      chat — TTFT (10 s) + TPOT (50 ms) SLOs (h=0).

This container is offline, so we model the two sources with length
distributions matching their published statistics (lognormal fits; lengths
clipped to < 2k tokens exactly as the paper restricts for latency-predictor
validity), tagged with task types and the paper's SLOs.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.slo import SLO, Request

CODE_SLO = SLO(e2e=30.0)
CHAT_SLO = SLO(ttft=10.0, tpot=0.050)


@dataclasses.dataclass(frozen=True)
class TaskProfile:
    name: str
    slo: SLO
    in_mu: float      # lognormal params for input length
    in_sigma: float
    out_mu: float
    out_sigma: float
    max_len: int = 2000


# Length statistics: ShareGPT chat turns skew short-in/medium-out; the
# Python-code set has short prompts and longer completions (whole files).
CODE_TASK = TaskProfile("code", CODE_SLO,
                        in_mu=4.6, in_sigma=0.7,     # median ~100 tokens
                        out_mu=5.8, out_sigma=0.45)  # median ~330 tokens
CHAT_TASK = TaskProfile("chat", CHAT_SLO,
                        in_mu=5.0, in_sigma=1.0,     # median ~150 tokens
                        out_mu=5.2, out_sigma=0.6)   # median ~180 tokens


def sample_requests(n: int, seed: int = 0,
                    profiles: Optional[List[TaskProfile]] = None,
                    mix=None) -> List[Request]:
    """Evenly mixed (paper §5.1) then shuffled with the run's seed."""
    profiles = profiles or [CODE_TASK, CHAT_TASK]
    mix = mix or [1.0 / len(profiles)] * len(profiles)
    rng = np.random.default_rng(seed)
    counts = (np.array(mix) * n).astype(int)
    counts[0] += n - counts.sum()
    reqs = []
    rid = 0
    for prof, c in zip(profiles, counts):
        li = np.clip(rng.lognormal(prof.in_mu, prof.in_sigma, c), 8,
                     prof.max_len).astype(int)
        lo = np.clip(rng.lognormal(prof.out_mu, prof.out_sigma, c), 4,
                     prof.max_len).astype(int)
        for a, b in zip(li, lo):
            reqs.append(Request(req_id=rid, task_type=prof.name,
                                input_len=int(a), output_len=int(b),
                                slo=prof.slo))
            rid += 1
    order = rng.permutation(len(reqs))
    reqs = [reqs[i] for i in order]
    for i, r in enumerate(reqs):
        r.req_id = i
    return reqs


def token_stream(n_tokens: int, vocab: int, seed: int = 0,
                 batch: int = 1) -> np.ndarray:
    """Synthetic token ids for engine/training runs."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=(batch, n_tokens), dtype=np.int32)
