"""Synthetic ShareGPT-style workloads.

The paper mixes two datasets with distinct task domains and SLO kinds:
  * Python-Code-23k-ShareGPT  [hf:ajibawa-2023/Python-Code-23k-ShareGPT]
      code generation — e2e-latency SLO (h=1).  SLO: 30 s (10× the ~3 s
      single-request time, per §5.1).
  * ShareGPT_Vicuna_unfiltered [hf:anon8231489123/ShareGPT_Vicuna_unfiltered]
      chat — TTFT (10 s) + TPOT (50 ms) SLOs (h=0).

This container is offline, so we model the two sources with length
distributions matching their published statistics (lognormal fits; lengths
clipped to < 2k tokens exactly as the paper restricts for latency-predictor
validity), tagged with task types and the paper's SLOs.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.slo import SLO, Request

CODE_SLO = SLO(e2e=30.0)
CHAT_SLO = SLO(ttft=10.0, tpot=0.050)


@dataclasses.dataclass(frozen=True)
class TaskProfile:
    name: str
    slo: SLO
    in_mu: float      # lognormal params for input length
    in_sigma: float
    out_mu: float
    out_sigma: float
    max_len: int = 2000


# Length statistics: ShareGPT chat turns skew short-in/medium-out; the
# Python-code set has short prompts and longer completions (whole files).
CODE_TASK = TaskProfile("code", CODE_SLO,
                        in_mu=4.6, in_sigma=0.7,     # median ~100 tokens
                        out_mu=5.8, out_sigma=0.45)  # median ~330 tokens
CHAT_TASK = TaskProfile("chat", CHAT_SLO,
                        in_mu=5.0, in_sigma=1.0,     # median ~150 tokens
                        out_mu=5.2, out_sigma=0.6)   # median ~180 tokens


def sample_requests(n: int, seed: int = 0,
                    profiles: Optional[List[TaskProfile]] = None,
                    mix=None) -> List[Request]:
    """Evenly mixed (paper §5.1) then shuffled with the run's seed."""
    profiles = profiles or [CODE_TASK, CHAT_TASK]
    mix = mix or [1.0 / len(profiles)] * len(profiles)
    rng = np.random.default_rng(seed)
    counts = (np.array(mix) * n).astype(int)
    counts[0] += n - counts.sum()
    reqs = []
    rid = 0
    for prof, c in zip(profiles, counts):
        li = np.clip(rng.lognormal(prof.in_mu, prof.in_sigma, c), 8,
                     prof.max_len).astype(int)
        lo = np.clip(rng.lognormal(prof.out_mu, prof.out_sigma, c), 4,
                     prof.max_len).astype(int)
        for a, b in zip(li, lo):
            reqs.append(Request(req_id=rid, task_type=prof.name,
                                input_len=int(a), output_len=int(b),
                                slo=prof.slo))
            rid += 1
    order = rng.permutation(len(reqs))
    reqs = [reqs[i] for i in order]
    for i, r in enumerate(reqs):
        r.req_id = i
    return reqs


def token_stream(n_tokens: int, vocab: int, seed: int = 0,
                 batch: int = 1) -> np.ndarray:
    """Synthetic token ids for engine/training runs."""
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=(batch, n_tokens), dtype=np.int32)


# ------------------------------------------------------------------ serving
def sample_serve_workload(n: int, vocab: int, seed: int = 0,
                          scale: float = 1.0, arrival_rate: float = 0.0,
                          rng: Optional[np.random.Generator] = None,
                          in_range=(16, 96), out_range=(8, 48)):
    """Small mixed chat/code token workload for live serving runs.

    Returns ``[(Request, prompt_tokens)]`` (the token-workload
    convention): alternating code (e2e SLO) and chat (TTFT+TPOT SLO)
    requests with uniform prompt/output lengths — launcher- and
    CI-sized, unlike the paper-statistics :func:`sample_requests`.
    ``scale`` loosens/tightens every SLO together; ``arrival_rate`` > 0
    spaces arrivals by an exponential (Poisson process) clock, 0 means
    everything arrives at t=0.
    """
    if rng is None:
        rng = np.random.default_rng(seed)
    out = []
    t = 0.0
    for i in range(n):
        code = i % 2 == 0
        slo = SLO(e2e=8.0 * scale) if code else SLO(ttft=3.0 * scale,
                                                    tpot=0.5 * scale)
        lin = int(rng.integers(*in_range))
        lout = int(rng.integers(*out_range))
        if arrival_rate > 0:
            t += float(rng.exponential(1.0 / arrival_rate))
        req = Request(req_id=i, task_type="code" if code else "chat",
                      input_len=lin, slo=slo, output_len=lout,
                      arrival_time=t)
        out.append((req, rng.integers(0, vocab, lin).astype(np.int32)))
    return out


# --------------------------------------------------------------- multi-turn
def sample_multiturn_requests(n_conversations: int, turns: int = 3,
                              seed: int = 0,
                              profile: Optional[TaskProfile] = None,
                              system_prompt_len: int = 128,
                              think_time: float = 2.0,
                              block_size: int = 16) -> List[Request]:
    """Request-level multi-turn chat workload (simulator/planner input).

    Each conversation opens with a shared system prompt and grows turn
    over turn: turn ``k``'s prompt is the full prior context (system
    prompt + earlier prompts and replies) plus fresh user tokens, so its
    ``cached_prefix`` — the block-aligned span a prefix-caching server
    already holds — covers everything but the new tail.  Turn 0 of every
    conversation after the first reuses the system prompt itself.
    Arrivals are spaced by exponential user think time; requests come
    back sorted by arrival with contiguous ids.
    """
    prof = profile or CHAT_TASK
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    for c in range(n_conversations):
        ctx = system_prompt_len          # tokens already in the convo
        t = float(rng.exponential(think_time))
        for k in range(turns):
            li_new = int(np.clip(rng.lognormal(prof.in_mu, prof.in_sigma),
                                 8, prof.max_len))
            lo = int(np.clip(rng.lognormal(prof.out_mu, prof.out_sigma),
                             4, prof.max_len))
            input_len = min(ctx + li_new, prof.max_len)
            if k > 0 or c > 0:
                # prior context (or the shared system prompt) is cached
                # at block granularity
                cached = (min(ctx, input_len - 1)
                          // block_size) * block_size
            else:
                cached = 0
            reqs.append(Request(req_id=0, task_type=prof.name,
                                input_len=input_len, output_len=lo,
                                slo=prof.slo, arrival_time=t,
                                cached_prefix=cached))
            ctx = input_len + lo
            t += float(rng.exponential(think_time))
    reqs.sort(key=lambda r: r.arrival_time)
    for i, r in enumerate(reqs):
        r.req_id = i
    return reqs


def sample_multiturn_token_requests(
        n_conversations: int, turns: int = 3, vocab: int = 97,
        seed: int = 0, system_prompt_len: int = 48,
        n_system_prompts: int = 2, user_len=(8, 24), reply_len: int = 8,
        max_new_tokens: int = 8, think_time: float = 0.05,
        profile: Optional[TaskProfile] = None):
    """Token-level multi-turn workload for engine-backed runs.

    Returns ``[(Request, prompt_tokens)]`` sorted by arrival.  Turn
    ``k``'s prompt is turn ``k-1``'s prompt followed by a synthetic
    assistant reply and fresh user tokens, and every conversation opens
    with one of ``n_system_prompts`` *shared* system prompts — so a
    prefix-caching engine serves the repeated span from cached pages.
    ``cached_prefix`` is left 0: the engine's radix index discovers the
    true cached span itself (the actual reply tokens it generated, not
    the synthetic stand-ins, decide what re-matches).
    """
    prof = profile or CHAT_TASK
    rng = np.random.default_rng(seed)
    sys_prompts = [rng.integers(0, vocab, system_prompt_len,
                                dtype=np.int32)
                   for _ in range(max(n_system_prompts, 1))]
    out = []
    for c in range(n_conversations):
        ctx = sys_prompts[c % len(sys_prompts)]
        t = float(rng.exponential(think_time))
        for k in range(turns):
            u = rng.integers(0, vocab,
                             int(rng.integers(user_len[0], user_len[1])),
                             dtype=np.int32)
            prompt = np.concatenate([ctx, u]).astype(np.int32)
            req = Request(req_id=0, task_type=prof.name,
                          input_len=len(prompt),
                          output_len=max_new_tokens, slo=prof.slo,
                          arrival_time=t)
            out.append((req, prompt))
            reply = rng.integers(0, vocab, reply_len, dtype=np.int32)
            ctx = np.concatenate([prompt, reply])
            t += float(rng.exponential(think_time))
    out.sort(key=lambda p: p[0].arrival_time)
    for i, (r, _) in enumerate(out):
        r.req_id = i
    return out
