from repro.models.config import (ATTN, SSM, MLAConfig, MoEConfig, ModelConfig,
                                 SSMConfig, reduced)
from repro.models.model import (forward_decode, forward_decode_paged,
                                forward_full, forward_prefill_paged,
                                init_params)
from repro.models.cache import (cache_spec, init_cache, init_paged_cache,
                                kv_bytes_per_token)
from repro.models.moe import ShardingCtx

__all__ = [
    "ATTN", "SSM", "MLAConfig", "MoEConfig", "ModelConfig", "SSMConfig",
    "reduced", "forward_decode", "forward_decode_paged", "forward_full",
    "forward_prefill_paged", "init_params", "cache_spec", "init_cache",
    "init_paged_cache", "kv_bytes_per_token", "ShardingCtx",
]
