from repro.models.config import (ATTN, SSM, MLAConfig, MoEConfig, ModelConfig,
                                 SSMConfig, reduced)
from repro.models.model import (forward_decode, forward_full, init_params)
from repro.models.cache import cache_spec, init_cache
from repro.models.moe import ShardingCtx

__all__ = [
    "ATTN", "SSM", "MLAConfig", "MoEConfig", "ModelConfig", "SSMConfig",
    "reduced", "forward_decode", "forward_full", "init_params",
    "cache_spec", "init_cache", "ShardingCtx",
]
