"""Mixture-of-Experts FFN: token-choice top-k router + ``jax.lax.ragged_dot``.

FLOP-exact dispatch: token-expert pairs are sorted by expert id and fed
through ``ragged_dot`` against the stacked expert weights — no dense
[T, E, C] dispatch tensor.

Distribution: when a ``ShardingCtx`` is provided the FFN runs inside
``jax.shard_map`` with tokens sharded over (dp_axes + (tp_axis,)) and expert
weights gathered per device (baseline strategy; see DESIGN.md §5 and the
§Perf log for the expert-parallel alternative).  Routing and the ragged
matmuls are then fully local.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# jax.shard_map landed in newer releases; older jax ships it under
# jax.experimental with a kwargs-compatible signature
try:
    _shard_map = jax.shard_map
except AttributeError:                                    # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map


class ShardingCtx(NamedTuple):
    """Mesh context threaded through model forward passes."""
    mesh: object                       # jax.sharding.Mesh
    dp_axes: tuple = ("data",)         # axes sharding the batch
    tp_axis: str = "model"             # axis sharding heads/ffn/experts
    seq_shard: bool = True             # shard seq over tp_axis inside MoE
    expert_parallel: bool = False      # expert-parallel MoE (psum combine)
    attn_sharding: str = "none"        # "auto": sequence-parallel attention
    fsdp_axes: tuple = ()              # axes the weights are fsdp-sharded on


def init_moe(key, cfg, dtype):
    e = cfg.moe
    d, ff = cfg.d_model, e.d_ff_expert
    ks = jax.random.split(key, 5)
    s, sf = d ** -0.5, ff ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e.num_experts)) * s).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e.num_experts, d, ff)) * s).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e.num_experts, d, ff)) * s).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e.num_experts, ff, d)) * sf).astype(dtype),
    }
    if e.num_shared_experts:
        ksh = jax.random.split(ks[4], 3)
        ff_sh = ff * e.num_shared_experts
        p["shared"] = {
            "w_gate": (jax.random.normal(ksh[0], (d, ff_sh)) * s).astype(dtype),
            "w_up": (jax.random.normal(ksh[1], (d, ff_sh)) * s).astype(dtype),
            "w_down": (jax.random.normal(ksh[2], (ff_sh, d)) * sf).astype(dtype),
        }
    return p


def _local_moe(x2d, router, w_gate, w_up, w_down, top_k: int):
    """Token-choice top-k MoE over a local token slab.

    x2d: [T, d].  Returns ([T, d], router probs [T, E] f32).
    """
    t, d = x2d.shape
    n_experts = router.shape[1]
    logits = jnp.dot(x2d.astype(jnp.float32), router)          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)        # [T, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    flat_expert = expert_idx.reshape(-1)                       # [T*k]
    order = jnp.argsort(flat_expert)                           # stable
    token_of = order // top_k                                  # source token
    xs = jnp.take(x2d, token_of, axis=0)                       # [T*k, d]
    group_sizes = jnp.bincount(flat_expert, length=n_experts).astype(jnp.int32)

    g = jax.lax.ragged_dot(xs, w_gate, group_sizes)
    u = jax.lax.ragged_dot(xs, w_up, group_sizes)
    h = jax.nn.silu(g) * u
    y = jax.lax.ragged_dot(h, w_down, group_sizes)             # [T*k, d]

    w = jnp.take(gate_vals.reshape(-1), order)[:, None].astype(y.dtype)
    out = jnp.zeros_like(x2d).at[token_of].add(y * w)
    return out, probs


def _local_moe_ep(x2d, router, w_gate, w_up, w_down, top_k: int,
                  tp_axis: str, num_experts: int, psum_axes=None):
    """Expert-parallel MoE shard (beyond-paper §Perf optimization).

    Runs inside shard_map with tokens REPLICATED over ``tp_axis`` and the
    expert weights SHARDED over it (w_*: [E_local, ...]).  Each device
    routes all tokens against the full router, computes only the pairs
    assigned to its local experts via ragged_dot (a zero dummy expert
    absorbs non-local pairs), and a psum over ``tp_axis`` combines the
    per-expert contributions — replacing the baseline's per-layer expert
    weight all-gather with one activation-sized all-reduce.
    """
    t, d = x2d.shape
    e_local = w_gate.shape[0]
    lo = jax.lax.axis_index(tp_axis) * e_local
    logits = jnp.dot(x2d.astype(jnp.float32), router)          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)        # [T, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    flat_expert = expert_idx.reshape(-1)                       # [T*k] global
    local_id = flat_expert - lo
    in_range = (local_id >= 0) & (local_id < e_local)
    sort_key = jnp.where(in_range, local_id, e_local)          # dummy last
    order = jnp.argsort(sort_key)
    token_of = order // top_k
    xs = jnp.take(x2d, token_of, axis=0)
    group_sizes = jnp.bincount(sort_key, length=e_local + 1).astype(jnp.int32)

    pad = lambda w: jnp.concatenate(
        [w, jnp.zeros((1,) + w.shape[1:], w.dtype)], axis=0)
    g = jax.lax.ragged_dot(xs, pad(w_gate), group_sizes)
    u = jax.lax.ragged_dot(xs, pad(w_up), group_sizes)
    h = jax.nn.silu(g) * u
    y = jax.lax.ragged_dot(h, pad(w_down), group_sizes)        # [T*k, d]

    w = jnp.take(gate_vals.reshape(-1), order) * \
        jnp.take(in_range, order)
    out = jnp.zeros_like(x2d).at[token_of].add(
        y * w[:, None].astype(y.dtype))
    out = jax.lax.psum(out, psum_axes if psum_axes is not None else tp_axis)
    return out, probs


def load_balance_loss(probs: jax.Array, expert_idx_probs: Optional[jax.Array],
                      top_k: int) -> jax.Array:
    """Switch-style auxiliary load-balancing loss (f·P formulation)."""
    n_experts = probs.shape[-1]
    # fraction of router prob mass per expert
    p_mean = jnp.mean(probs, axis=0)
    # fraction of tokens whose argmax is each expert
    hard = jax.nn.one_hot(jnp.argmax(probs, axis=-1), n_experts)
    f_mean = jnp.mean(hard, axis=0)
    return n_experts * jnp.sum(f_mean * p_mean)


def moe_ffn(params, cfg, x, ctx: Optional[ShardingCtx] = None):
    """MoE FFN over x [B, S, D].  Returns (out, aux_loss f32 scalar)."""
    e = cfg.moe
    b, s, d = x.shape

    def body(xx, router, wg, wu, wd):
        bb, ss, _ = xx.shape
        out, probs = _local_moe(xx.reshape(bb * ss, d), router, wg, wu, wd,
                                e.top_k)
        aux = load_balance_loss(probs, None, e.top_k)
        return out.reshape(bb, ss, d), aux

    if ctx is None or ctx.mesh is None:
        out, aux = body(x, params["router"], params["w_gate"],
                        params["w_up"], params["w_down"])
    else:
        mesh = ctx.mesh
        dp = tuple(ctx.dp_axes) if isinstance(ctx.dp_axes, (tuple, list)) \
            else (ctx.dp_axes,)
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
        tp_size = mesh.shape[ctx.tp_axis]
        batch_axes = dp if (dp_size > 1 and b % dp_size == 0) else ()
        seq_axis = ctx.tp_axis if (ctx.seq_shard and s > 1
                                   and s % tp_size == 0) else None
        if ctx.expert_parallel and e.num_experts % tp_size == 0:
            # Experts sharded over tp inside the shard_map; psum combines
            # per-expert contributions (see _local_moe_ep).  For SMALL token
            # counts (decode) with FSDP weights, the 2D variant additionally
            # keeps the ff dim sharded over the fsdp axes — the stored
            # layout — so NO weight movement happens at all; the psum then
            # runs over (tp + fsdp) axes on tiny activations.
            fsdp_axes = tuple(ctx.fsdp_axes or ())
            use_2d = bool(fsdp_axes) and b * s <= 4096
            if use_2d:
                xspec = P(None, None, None)
                wspec_gu = P(ctx.tp_axis, None, fsdp_axes)
                wspec_d = P(ctx.tp_axis, fsdp_axes, None)
                psum_axes = (ctx.tp_axis,) + fsdp_axes
                pmean_axes = ()
            else:
                xspec = P(batch_axes or None, None, None)
                wspec_gu = P(ctx.tp_axis, None, None)
                wspec_d = P(ctx.tp_axis, None, None)
                psum_axes = (ctx.tp_axis,)
                pmean_axes = batch_axes

            def smbody_ep(xx, router, wg, wu, wd):
                bb, ss, _ = xx.shape
                out, probs = _local_moe_ep(
                    xx.reshape(bb * ss, d), router, wg, wu, wd, e.top_k,
                    ctx.tp_axis, e.num_experts, psum_axes=psum_axes)
                aux = load_balance_loss(probs, None, e.top_k)
                if pmean_axes:
                    aux = jax.lax.pmean(aux, pmean_axes)
                return out.reshape(bb, ss, d), aux

            out, aux = _shard_map(
                smbody_ep, mesh=mesh,
                in_specs=(xspec, P(None, None), wspec_gu, wspec_gu, wspec_d),
                out_specs=(xspec, P()),
            )(x, params["router"], params["w_gate"], params["w_up"],
              params["w_down"])
        else:
            xspec = P(batch_axes or None, seq_axis, None)
            rep2, rep3 = P(None, None), P(None, None, None)
            pmean_axes = batch_axes + ((seq_axis,) if seq_axis else ())

            def smbody(xx, router, wg, wu, wd):
                out, aux = body(xx, router, wg, wu, wd)
                if pmean_axes:
                    aux = jax.lax.pmean(aux, pmean_axes)
                return out, aux

            out, aux = _shard_map(
                smbody, mesh=mesh,
                in_specs=(xspec, rep2, rep3, rep3, rep3),
                out_specs=(xspec, P()),
            )(x, params["router"], params["w_gate"], params["w_up"],
              params["w_down"])

    if e.num_shared_experts:
        sh = params["shared"]
        g = jnp.dot(x, sh["w_gate"])
        u = jnp.dot(x, sh["w_up"])
        out = out + jnp.dot(jax.nn.silu(g) * u, sh["w_down"])
    return out, aux.astype(jnp.float32)
