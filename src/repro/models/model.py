"""Unified decoder-only model covering all supported families.

A model is (init_params, forward).  The layer stack is driven by
``cfg.layer_pattern`` — attention (GQA or MLA) blocks, Mamba2 SSD blocks, or
a mix (hybrid).  MoE configs replace the dense FFN on non-dense layers.
Audio (MusicGen) models embed K codebooks and emit K logit heads; VLM
backbones accept precomputed ``embeds`` instead of token ids.

The forward pass is written against plain jnp ops so that XLA's SPMD
partitioner can shard it from the in/out shardings alone; the MoE FFN is the
one explicitly shard_mapped component (see moe.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ssm as ssm_mod
from repro.models.cache import write_prefill, write_prefill_paged
from repro.models.config import ATTN, SSM, ModelConfig
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm
from repro.models.moe import ShardingCtx, init_moe, moe_ffn


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _is_moe_layer(cfg: ModelConfig, i: int) -> bool:
    return cfg.moe is not None and i not in cfg.moe.dense_layers


def _init_attn_block(key, cfg, i, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"attn_norm": init_norm(cfg, cfg.d_model),
         "mlp_norm": init_norm(cfg, cfg.d_model)}
    if cfg.mla is not None:
        p["attn"] = attn.init_mla(k1, cfg, dtype)
    else:
        p["attn"] = attn.init_gqa(k1, cfg, dtype)
    if _is_moe_layer(cfg, i):
        p["moe"] = init_moe(k2, cfg, dtype)
    else:
        ff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.d_ff_dense:
            ff = cfg.moe.d_ff_dense
        p["mlp"] = init_mlp(k3, cfg, cfg.d_model, ff, dtype)
    return p


def init_params(key, cfg: ModelConfig):
    dtype = _dtype(cfg)
    keys = jax.random.split(key, cfg.num_layers + 3)
    s = cfg.d_model ** -0.5
    nc = cfg.num_codebooks or 1
    if nc > 1:
        embed = (jax.random.normal(keys[0], (nc, cfg.vocab_size, cfg.d_model))
                 * s).astype(dtype)
    else:
        embed = (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model))
                 * s).astype(dtype)
    params = {"embed": embed, "final_norm": init_norm(cfg, cfg.d_model),
              "layers": []}
    if not cfg.tie_embeddings:
        if nc > 1:
            params["lm_head"] = (jax.random.normal(
                keys[1], (nc, cfg.d_model, cfg.vocab_size)) * s).astype(dtype)
        else:
            params["lm_head"] = (jax.random.normal(
                keys[1], (cfg.d_model, cfg.vocab_size)) * s).astype(dtype)
    shared_block = None
    for i, kind in enumerate(cfg.layer_pattern):
        k = keys[2 + i]
        if kind == SSM:
            params["layers"].append(
                {"norm": init_norm(cfg, cfg.d_model),
                 "mamba": ssm_mod.init_mamba2(k, cfg, dtype)})
        else:
            if cfg.shared_attn_weights:
                if shared_block is None:
                    shared_block = _init_attn_block(k, cfg, i, dtype)
                    params["shared_block"] = shared_block
                # empty dict marker (no leaves): weights live in shared_block
                params["layers"].append({})
            else:
                params["layers"].append(_init_attn_block(k, cfg, i, dtype))
    return params


def embed_tokens(params, cfg, tokens):
    nc = cfg.num_codebooks or 1
    if nc > 1:
        # tokens [B, S, K]
        embs = [jnp.take(params["embed"][k], tokens[..., k], axis=0)
                for k in range(nc)]
        return sum(embs)
    return jnp.take(params["embed"], tokens, axis=0)


def lm_head(params, cfg, x):
    nc = cfg.num_codebooks or 1
    if cfg.tie_embeddings:
        if nc > 1:
            return jnp.einsum("bsd,kvd->bskv", x, params["embed"])
        return jnp.dot(x, params["embed"].T)
    if nc > 1:
        return jnp.einsum("bsd,kdv->bskv", x, params["lm_head"])
    return jnp.dot(x, params["lm_head"])


def _attn_block_full(block, cfg, x, positions, ctx):
    h, kv_out = (attn.mla_full if cfg.mla is not None else attn.gqa_full)(
        block["attn"], cfg, apply_norm(x, block["attn_norm"], cfg), positions,
        ctx=ctx)
    x = x + h
    y = apply_norm(x, block["mlp_norm"], cfg)
    aux = jnp.float32(0.0)
    if "moe" in block:
        y, aux = moe_ffn(block["moe"], cfg, y, ctx)
    else:
        y = apply_mlp(y, block["mlp"], cfg)
    return x + y, kv_out, aux


def _attn_block_decode(block, cfg, x, positions, layer_cache, cache_pos, ctx):
    xin = apply_norm(x, block["attn_norm"], cfg)
    if cfg.mla is not None:
        h, ckv, kpe = attn.mla_decode(block["attn"], cfg, xin, positions,
                                      layer_cache["ckv"], layer_cache["kpe"],
                                      cache_pos)
        new_cache = {"ckv": ckv, "kpe": kpe}
    else:
        h, new_cache = attn.gqa_decode(
            block["attn"], cfg, xin, positions,
            layer_cache["k"], layer_cache["v"], cache_pos,
            k_scale=layer_cache.get("k_scale"),
            v_scale=layer_cache.get("v_scale"))
    x = x + h
    y = apply_norm(x, block["mlp_norm"], cfg)
    if "moe" in block:
        y, _ = moe_ffn(block["moe"], cfg, y, ctx)
    else:
        y = apply_mlp(y, block["mlp"], cfg)
    return x + y, new_cache


def _default_positions(cfg, batch, seq, offset=0):
    pos = offset + jnp.arange(seq, dtype=jnp.int32)[None, :]
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[..., None], (batch, seq, 3))
    return pos


def forward_full(params, cfg: ModelConfig, *, tokens=None, embeds=None,
                 positions=None, cache=None,
                 ctx: Optional[ShardingCtx] = None, remat: bool = False,
                 last_only: bool = False):
    """Train / prefill pass over a whole sequence.

    ``last_only`` applies the LM head to the final position only (prefill
    path — avoids materializing [B, S, V] logits).
    Returns (logits, cache_or_None, aux_loss).
    """
    x = embeds if embeds is not None else embed_tokens(params, cfg, tokens)
    b, s = x.shape[0], x.shape[1]
    if positions is None:
        positions = _default_positions(cfg, b, s)
    aux_total = jnp.float32(0.0)

    # Full sequence-parallelism (§Perf): in "auto" mode keep the residual
    # stream sharded (batch over dp, seq over tp) BETWEEN blocks too —
    # norms/MLPs are elementwise over seq, so only attention k/v gathers
    # remain, removing the per-layer gather↔scatter ping-pong.
    from repro.models.attention import _constrain, _seq_parallel_wanted
    seq_par = _seq_parallel_wanted(cfg, ctx, s) and not cfg.ssm_layers
    dpb = None
    if seq_par:
        dp_size = 1
        for a in ctx.dp_axes:
            dp_size *= ctx.mesh.shape[a]
        dpb = ctx.dp_axes if b % dp_size == 0 else None
        x = _constrain(x, ctx, dpb, ctx.tp_axis, None)

    for i, kind in enumerate(cfg.layer_pattern):
        block = params["layers"][i] or params.get("shared_block")

        if kind == SSM:
            def layer_fn(xx, blk):
                h, state = ssm_mod.mamba2_full(
                    blk["mamba"], cfg, apply_norm(xx, blk["norm"], cfg))
                return xx + h, state, jnp.float32(0.0)
        else:
            def layer_fn(xx, blk):
                return _attn_block_full(blk, cfg, xx, positions, ctx)

        if remat:
            layer_fn = jax.checkpoint(layer_fn)
        x, kv_out, aux = layer_fn(x, block)
        if seq_par:
            x = _constrain(x, ctx, dpb, ctx.tp_axis, None)
        aux_total = aux_total + aux
        if cache is not None:
            cache = write_prefill(cache, i, kv_out, cfg)

    if last_only:
        x = x[:, -1:]
    x = apply_norm(x, params["final_norm"], cfg)
    logits = lm_head(params, cfg, x)
    if cache is not None:
        cache["pos"] = cache["pos"] + s
    return logits, cache, aux_total / max(1, len(cfg.attn_layers))


def forward_decode(params, cfg: ModelConfig, *, tokens=None, embeds=None,
                   positions=None, cache=None,
                   ctx: Optional[ShardingCtx] = None):
    """One-token decode step. tokens: [B, 1] (or [B,1,K] audio).

    Returns (logits, new_cache).
    """
    assert cache is not None
    x = embeds if embeds is not None else embed_tokens(params, cfg, tokens)
    b = x.shape[0]
    cache_pos = cache["pos"]
    if positions is None:
        pos = cache_pos[:, None]
        if cfg.mrope_sections:
            pos = jnp.broadcast_to(pos[..., None], (b, 1, 3))
        positions = pos
    new_layers = []
    for i, kind in enumerate(cfg.layer_pattern):
        block = params["layers"][i] or params.get("shared_block")
        layer_cache = cache["layers"][i]
        if kind == SSM:
            h, conv, st = ssm_mod.mamba2_decode(
                block["mamba"], cfg, apply_norm(x, block["norm"], cfg),
                layer_cache["conv"], layer_cache["ssm"])
            x = x + h
            new_layers.append({"conv": conv, "ssm": st})
        else:
            x, new_lc = _attn_block_decode(block, cfg, x, positions,
                                           layer_cache, cache_pos, ctx)
            new_layers.append(new_lc)
    x = apply_norm(x, params["final_norm"], cfg)
    logits = lm_head(params, cfg, x)
    return logits, {"pos": cache_pos + 1, "layers": new_layers}


def forward_decode_paged(params, cfg: ModelConfig, *, tokens=None,
                         embeds=None, positions=None, cache=None,
                         ctx: Optional[ShardingCtx] = None):
    """One-token decode step against a paged KV pool.

    ``cache`` is an :func:`repro.models.cache.init_paged_cache` pytree:
    attention layers hold shared page arrays plus per-slot block tables;
    SSM layers keep their per-slot state.  New tokens are written in
    place into their pages (O(B) scatter) and attention reads through
    the block table.  Returns (logits, new_cache).
    """
    assert cache is not None
    x = embeds if embeds is not None else embed_tokens(params, cfg, tokens)
    b = x.shape[0]
    cache_pos = cache["pos"]
    block_tables = cache["block_tables"]
    if positions is None:
        pos = cache_pos[:, None]
        if cfg.mrope_sections:
            pos = jnp.broadcast_to(pos[..., None], (b, 1, 3))
        positions = pos
    new_layers = []
    for i, kind in enumerate(cfg.layer_pattern):
        block = params["layers"][i] or params.get("shared_block")
        layer_cache = cache["layers"][i]
        if kind == SSM:
            h, conv, st = ssm_mod.mamba2_decode(
                block["mamba"], cfg, apply_norm(x, block["norm"], cfg),
                layer_cache["conv"], layer_cache["ssm"])
            x = x + h
            new_layers.append({"conv": conv, "ssm": st})
            continue
        xin = apply_norm(x, block["attn_norm"], cfg)
        if cfg.mla is not None:
            h, ckv, kpe = attn.mla_decode_paged(
                block["attn"], cfg, xin, positions, layer_cache["ckv"],
                layer_cache["kpe"], block_tables, cache_pos)
            new_lc = {"ckv": ckv, "kpe": kpe}
        else:
            h, new_lc = attn.gqa_decode_paged(
                block["attn"], cfg, xin, positions, layer_cache,
                block_tables, cache_pos)
        x = x + h
        y = apply_norm(x, block["mlp_norm"], cfg)
        if "moe" in block:
            y, _ = moe_ffn(block["moe"], cfg, y, ctx)
        else:
            y = apply_mlp(y, block["mlp"], cfg)
        x = x + y
        new_layers.append(new_lc)
    x = apply_norm(x, params["final_norm"], cfg)
    logits = lm_head(params, cfg, x)
    return logits, {"pos": cache_pos + 1, "block_tables": block_tables,
                    "layers": new_layers}


def forward_prefill_paged(params, cfg: ModelConfig, *, tokens=None,
                          embeds=None, positions=None, cache=None,
                          slot=0, length=None,
                          ctx: Optional[ShardingCtx] = None):
    """Whole-prompt prefill of ONE request written *in place* into
    ``slot``'s pages of the shared pool (no per-prefill full-length
    cache allocation, no O(pool) commit copy — each layer's K/V is an
    O(prompt) scatter through the slot's block table; padded positions
    land on the null page).

    tokens: [1, Lpad]; ``length``: actual prompt length.  Returns
    (last-token logits [V], new_cache).
    """
    assert cache is not None
    x = embeds if embeds is not None else embed_tokens(params, cfg, tokens)
    b, s = x.shape[0], x.shape[1]
    if length is None:
        length = s
    if positions is None:
        positions = _default_positions(cfg, b, s)
    for i, kind in enumerate(cfg.layer_pattern):
        block = params["layers"][i] or params.get("shared_block")
        if kind == SSM:
            h, state = ssm_mod.mamba2_full(
                block["mamba"], cfg, apply_norm(x, block["norm"], cfg))
            x = x + h
            kv_out = state
        else:
            x, kv_out, _ = _attn_block_full(block, cfg, x, positions, ctx)
        cache = write_prefill_paged(cache, i, kv_out, cfg, slot, length)
    x = apply_norm(x, params["final_norm"], cfg)
    logits = lm_head(params, cfg, x[:, length - 1][:, None])
    cache["pos"] = cache["pos"].at[slot].set(length)
    return logits[0, 0], cache


def forward_chunk_paged(params, cfg: ModelConfig, *, tokens=None,
                        embeds=None, cache=None, slot=0, length=None,
                        ctx: Optional[ShardingCtx] = None):
    """Chunked-prefill step for ONE slot against the paged pool
    (Sarathi-style).  The chunk attends to the slot's gathered prefix
    pages plus itself, then is scattered into its pages in place.

    The chunk starts at ``cache["pos"][slot]`` — which need not be 0:
    a request aliasing a cached prefix (shared-prefix KV reuse) presets
    ``pos`` to the cached length and prefills only its unique suffix
    through this path.

    tokens: [1, C]; ``length`` (static or traced; default C) is the
    number of valid rows — padded power-of-two suffix buckets reuse one
    compilation per bucket.  Padded rows are never written to pages and
    never attended by valid queries; ``length < C`` is only meaningful
    for attention-only archs (SSM state consumes all C rows in order).
    Returns (valid-final logits [1,1,V], new_cache).
    """
    assert cache is not None
    assert cfg.mla is None, "chunked prefill: MLA not supported"
    x = embeds if embeds is not None else embed_tokens(params, cfg, tokens)
    b, c = x.shape[0], x.shape[1]
    if length is None:
        length = c
    length = jnp.asarray(length, jnp.int32)
    pos0 = cache["pos"][slot]
    bt = jax.lax.dynamic_slice_in_dim(cache["block_tables"], slot, 1)
    positions = pos0 + jnp.arange(c, dtype=jnp.int32)[None, :]
    positions = jnp.broadcast_to(positions, (b, c))
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(positions[..., None], (b, c, 3))
    for i, kind in enumerate(cfg.layer_pattern):
        block = params["layers"][i] or params.get("shared_block")
        layer_cache = cache["layers"][i]
        if kind == SSM:
            h, (conv, st) = ssm_mod.mamba2_full(
                block["mamba"], cfg, apply_norm(x, block["norm"], cfg),
                conv_state=jax.lax.dynamic_slice_in_dim(
                    layer_cache["conv"], slot, 1).astype(x.dtype),
                ssm_state=jax.lax.dynamic_slice_in_dim(
                    layer_cache["ssm"], slot, 1))
            x = x + h
            cache["layers"][i] = {
                "conv": layer_cache["conv"].at[slot].set(
                    conv[0].astype(layer_cache["conv"].dtype)),
                "ssm": layer_cache["ssm"].at[slot].set(st[0])}
        else:
            xin = apply_norm(x, block["attn_norm"], cfg)
            h, new_lc = attn.gqa_continue_paged(
                block["attn"], cfg, xin, positions, layer_cache, bt, pos0,
                n=length)
            x = x + h
            y = apply_norm(x, block["mlp_norm"], cfg)
            if "moe" in block:
                y, _ = moe_ffn(block["moe"], cfg, y, ctx)
            else:
                y = apply_mlp(y, block["mlp"], cfg)
            x = x + y
            cache["layers"][i] = new_lc
    x = apply_norm(x, params["final_norm"], cfg)
    x = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
    logits = lm_head(params, cfg, x)
    cache["pos"] = cache["pos"].at[slot].add(length)
    return logits, cache


def forward_chunk(params, cfg: ModelConfig, *, tokens=None, embeds=None,
                  cache=None, ctx: Optional[ShardingCtx] = None):
    """Chunked-prefill step: process a chunk of C tokens against a cache
    already holding ``cache["pos"]`` tokens (Sarathi-style).  Supports
    attention (GQA) and SSM layers; MLA archs use whole-sequence prefill.

    Returns (logits for the chunk's last position [B,1,V], cache).
    """
    assert cache is not None
    assert cfg.mla is None, "chunked prefill: MLA not supported"
    x = embeds if embeds is not None else embed_tokens(params, cfg, tokens)
    b, c = x.shape[0], x.shape[1]
    # positions from the cache pointer (uniform across batch by contract)
    pos0 = cache["pos"][0]
    positions = pos0 + jnp.arange(c, dtype=jnp.int32)[None, :]
    positions = jnp.broadcast_to(positions, (b, c))
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(positions[..., None], (b, c, 3))
    new_layers = []
    for i, kind in enumerate(cfg.layer_pattern):
        block = params["layers"][i] or params.get("shared_block")
        layer_cache = cache["layers"][i]
        if kind == SSM:
            h, (conv, st) = ssm_mod.mamba2_full(
                block["mamba"], cfg, apply_norm(x, block["norm"], cfg),
                conv_state=layer_cache["conv"].astype(x.dtype),
                ssm_state=layer_cache["ssm"])
            x = x + h
            new_layers.append({"conv": conv.astype(
                layer_cache["conv"].dtype), "ssm": st})
        else:
            xin = apply_norm(x, block["attn_norm"], cfg)
            h, kc, vc = attn.gqa_continue(
                block["attn"], cfg, xin, positions,
                layer_cache["k"], layer_cache["v"], pos0)
            x = x + h
            y = apply_norm(x, block["mlp_norm"], cfg)
            if "moe" in block:
                y, _ = moe_ffn(block["moe"], cfg, y, ctx)
            else:
                y = apply_mlp(y, block["mlp"], cfg)
            x = x + y
            new_layers.append({"k": kc, "v": vc})
    x = apply_norm(x, params["final_norm"], cfg)
    logits = lm_head(params, cfg, x[:, -1:])
    return logits, {"pos": cache["pos"] + c, "layers": new_layers}
