"""Model configuration for every supported architecture family.

A single ``ModelConfig`` dataclass describes dense, MoE, MLA, SSM, hybrid,
VLM-backbone and audio-decoder architectures.  Family-specific fields are
optional and ignored by families that do not use them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Layer kinds used by hybrid stacks.
ATTN = "attn"
SSM = "ssm"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    # layer indices that stay dense (e.g. deepseek-v2 layer 0)
    dense_layers: Tuple[int, ...] = ()
    d_ff_dense: int = 0          # ffn width for the dense layers
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2, arXiv:2405.04434)."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 => no q compression (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD (arXiv:2405.21060)."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                # 0 for attn-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 => d_model // num_heads
    # --- attention options ---
    rope_theta: float = 10000.0
    qk_norm: bool = False
    sliding_window: int = 0       # 0 => full attention
    mrope_sections: Tuple[int, ...] = ()   # M-RoPE (qwen2-vl)
    attn_logit_softcap: float = 0.0
    # --- mlp options ---
    mlp_type: str = "swiglu"      # swiglu | gelu
    # --- norms ---
    norm_type: str = "rmsnorm"    # rmsnorm | layernorm
    norm_eps: float = 1e-5
    # --- family-specific ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: one entry per layer, "attn" or "ssm". empty => uniform family.
    layer_pattern: Tuple[str, ...] = ()
    # hybrid (zamba2-style): attention blocks share a single set of weights
    shared_attn_weights: bool = False
    # --- audio (musicgen): K parallel codebooks, K output heads ---
    num_codebooks: int = 0
    # --- vlm: backbone consumes extra patch embeddings via input stub ---
    uses_extra_embeds: bool = False
    # --- misc ---
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    max_seq_len: int = 32768
    source: str = ""              # citation

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family in ("ssm",) and not self.layer_pattern:
            object.__setattr__(self, "layer_pattern",
                               tuple([SSM] * self.num_layers))
        if not self.layer_pattern:
            object.__setattr__(self, "layer_pattern",
                               tuple([ATTN] * self.num_layers))
        assert len(self.layer_pattern) == self.num_layers

    # ---------- derived quantities ----------
    @property
    def attn_layers(self) -> Tuple[int, ...]:
        return tuple(i for i, k in enumerate(self.layer_pattern) if k == ATTN)

    @property
    def ssm_layers(self) -> Tuple[int, ...]:
        return tuple(i for i, k in enumerate(self.layer_pattern) if k == SSM)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d = self.d_model
        n = 0
        n += self.vocab_size * d * (self.num_codebooks or 1)  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d * (self.num_codebooks or 1)
        seen_shared = False
        for i, kind in enumerate(self.layer_pattern):
            if kind == SSM:
                n += self._ssm_layer_params()
            else:
                if self.shared_attn_weights and seen_shared:
                    continue
                seen_shared = True
                n += self._attn_layer_params(i)
        return n

    def _attn_layer_params(self, layer_idx: int) -> int:
        d = self.d_model
        if self.mla is not None:
            m = self.mla
            qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
            n = d * (self.num_heads * qk_hd)                     # Wq
            n += d * (m.kv_lora_rank + m.qk_rope_head_dim)       # down + k_rope
            n += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            n += self.num_heads * m.v_head_dim * d               # Wo
        else:
            hd = self.head_dim
            n = d * self.num_heads * hd        # Wq
            n += 2 * d * self.num_kv_heads * hd  # Wk, Wv
            n += self.num_heads * hd * d       # Wo
        # mlp
        if self.moe is not None and layer_idx not in self.moe.dense_layers:
            e = self.moe
            per = 3 if self.mlp_type == "swiglu" else 2
            n += e.num_experts * per * d * e.d_ff_expert
            n += e.num_shared_experts * per * d * e.d_ff_expert
            n += d * e.num_experts                     # router
        else:
            ff = (self.moe.d_ff_dense if (self.moe and self.moe.d_ff_dense)
                  else self.d_ff)
            per = 3 if self.mlp_type == "swiglu" else 2
            n += per * d * ff
        return n

    def _ssm_layer_params(self) -> int:
        assert self.ssm is not None
        d = self.d_model
        s = self.ssm
        di = s.d_inner(d)
        nh = s.n_heads(d)
        n = d * (2 * di + 2 * s.d_state * 1 + nh)  # in_proj (z,x,B,C,dt) approx
        n += d * di                                 # out proj
        n += di * s.d_conv                          # conv
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        e = self.moe
        per = 3 if self.mlp_type == "swiglu" else 2
        full_expert = per * d * e.d_ff_expert
        inactive = 0
        for i in range(self.num_layers):
            if self.layer_pattern[i] == ATTN and i not in e.dense_layers:
                inactive += (e.num_experts - e.top_k) * full_expert
        return self.param_count() - inactive


def reduced(cfg: ModelConfig, *, num_layers: int = 2, d_model: int = 256,
            vocab_size: int = 512, max_experts: int = 4) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests."""
    scale = d_model / cfg.d_model
    num_heads = max(2, min(4, cfg.num_heads)) if cfg.num_heads else 0
    num_kv = max(1, min(num_heads, max(1, int(cfg.num_kv_heads * num_heads
                                              / max(cfg.num_heads, 1)))))
    head_dim = d_model // num_heads if num_heads else 0
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe,
            num_experts=min(max_experts, cfg.moe.num_experts),
            top_k=min(2, cfg.moe.top_k),
            d_ff_expert=max(64, int(cfg.moe.d_ff_expert * scale)),
            num_shared_experts=min(1, cfg.moe.num_shared_experts),
            dense_layers=tuple(i for i in cfg.moe.dense_layers if i < num_layers),
            d_ff_dense=max(64, int(cfg.moe.d_ff_dense * scale)) if cfg.moe.d_ff_dense else 0,
        )
    mla = None
    if cfg.mla is not None:
        mla = dataclasses.replace(
            cfg.mla, kv_lora_rank=64, qk_nope_head_dim=head_dim,
            qk_rope_head_dim=max(8, head_dim // 2), v_head_dim=head_dim)
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, d_state=16, head_dim=32,
                                  chunk_size=32)
    if cfg.layer_pattern and SSM in cfg.layer_pattern and ATTN in cfg.layer_pattern:
        pattern = tuple([SSM, ATTN][: num_layers]) if num_layers >= 2 else (SSM,)
    elif cfg.family == "ssm":
        pattern = tuple([SSM] * num_layers)
    else:
        pattern = tuple([ATTN] * num_layers)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        num_layers=num_layers,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim,
        d_ff=max(128, int(cfg.d_ff * scale)) if cfg.d_ff else 0,
        vocab_size=vocab_size,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        mrope_sections=(head_dim // 2 - 2 * (head_dim // 8),
                        head_dim // 8, head_dim // 8)
        if cfg.mrope_sections else (),
        moe=moe, mla=mla, ssm=ssm,
        layer_pattern=pattern,
        max_seq_len=512,
    )
