"""Shared layer primitives: norms, rotary embeddings (incl. M-RoPE), MLPs."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- norms
def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, weight: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dt)


def apply_norm(x, params, cfg):
    if cfg.norm_type == "layernorm":
        return layernorm(x, params["scale"], params["bias"], cfg.norm_eps)
    return rmsnorm(x, params["scale"], cfg.norm_eps)


def init_norm(cfg, d):
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


# ---------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for half the head dim. [hd/2] f32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float,
                 mrope_sections: Tuple[int, ...] = ()) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables.

    positions: [..., seq] int32 for 1-D RoPE, or [..., seq, 3] for M-RoPE
    (temporal, height, width position ids — Qwen2-VL arXiv:2409.12191).
    Returns cos, sin of shape [..., seq, head_dim/2] f32.
    """
    inv = rope_freqs(head_dim, theta)  # [hd/2]
    if mrope_sections:
        assert positions.shape[-1] == 3, "M-RoPE needs 3-d position ids"
        # angles per component: [..., seq, 3, hd/2]
        ang3 = positions[..., None].astype(jnp.float32) * inv  # [...,seq,3,hd/2]
        # per-frequency component selector from section layout
        sec = jnp.concatenate([
            jnp.full((s,), i, dtype=jnp.int32)
            for i, s in enumerate(mrope_sections)])  # [hd/2]
        onehot = jax.nn.one_hot(sec, 3, dtype=jnp.float32)  # [hd/2, 3]
        ang = jnp.einsum("...kf,fk->...f", ang3, onehot)
    else:
        ang = positions[..., None].astype(jnp.float32) * inv  # [...,seq,hd/2]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., seq, heads, head_dim]; cos/sin: [..., seq, head_dim/2]."""
    dt = x.dtype
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    c = cos[..., None, :]  # broadcast over heads
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------- mlp
def swiglu(x, w_gate, w_up, w_down):
    g = jnp.dot(x, w_gate)
    u = jnp.dot(x, w_up)
    return jnp.dot(jax.nn.silu(g) * u, w_down)


def gelu_mlp(x, w_up, w_down):
    return jnp.dot(jax.nn.gelu(jnp.dot(x, w_up)), w_down)


def init_mlp(key, cfg, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_ff = d_ff ** -0.5
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
            "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
            "w_down": (jax.random.normal(k3, (d_ff, d_model)) * s_ff).astype(dtype),
        }
    return {
        "w_up": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(k2, (d_ff, d_model)) * s_ff).astype(dtype),
    }


def apply_mlp(x, params, cfg):
    if cfg.mlp_type == "swiglu":
        return swiglu(x, params["w_gate"], params["w_up"], params["w_down"])
    return gelu_mlp(x, params["w_up"], params["w_down"])
