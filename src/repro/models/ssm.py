"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD forward for train/prefill (quadratic inside a chunk, linear
recurrence across chunks via ``lax.scan``/associative form) and an O(1)
single-token decode step against a recurrent state cache.

Layout conventions:
  x (inner)    [B, S, nh, hd]
  B, C         [B, S, ds]          (n_groups = 1)
  dt           [B, S, nh]          (after softplus)
  ssm state    [B, nh, hd, ds]
  conv state   [B, d_conv-1, d_inner + 2*ds]
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm


def init_mamba2(key, cfg, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    ds = s.d_state
    conv_ch = di + 2 * ds
    d_in_proj = 2 * di + 2 * ds + nh
    ks = jax.random.split(key, 4)
    sc = d ** -0.5
    return {
        "in_proj": (jax.random.normal(ks[0], (d, d_in_proj)) * sc).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_ch)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[3], (di, d)) * di ** -0.5).astype(dtype),
    }


def _split_in_proj(cfg, zxbcdt):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    ds = s.d_state
    nh = s.n_heads(d)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: di + di + 2 * ds]
    dt = zxbcdt[..., di + di + 2 * ds:]
    assert dt.shape[-1] == nh
    return z, xbc, dt


def _causal_conv_full(xbc, conv_w, conv_b, conv_state=None):
    """xbc: [B, S, C]; conv_w [K, C] depthwise.  Returns (y, new_state)."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xbc], axis=1)  # [B, S+K-1, C]
    # depthwise causal conv via stacked shifts (K is tiny, typically 4)
    y = sum(xp[:, i: i + xbc.shape[1], :] * conv_w[i] for i in range(k))
    y = jax.nn.silu(y + conv_b)
    new_state = xp[:, -(k - 1):, :] if k > 1 else pad[:, :0]
    return y, new_state


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int,
                init_state=None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: [B,S,nh,hd]; dt: [B,S,nh] (post-softplus); A: [nh] (negative);
    Bm, Cm: [B,S,ds].  Returns (y [B,S,nh,hd], final_state [B,nh,hd,ds]).
    S must be a multiple of ``chunk``.
    """
    b, s, nh, hd = x.shape
    ds = Bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    f32 = jnp.float32
    xc = x.reshape(b, nc, chunk, nh, hd).astype(f32)
    dtc = dt.reshape(b, nc, chunk, nh).astype(f32)
    Bc = Bm.reshape(b, nc, chunk, ds).astype(f32)
    Cc = Cm.reshape(b, nc, chunk, ds).astype(f32)

    dA = dtc * A[None, None, None, :]           # [b,nc,q,nh]  (negative)
    cum = jnp.cumsum(dA, axis=2)                # running log-decay in chunk
    # --- intra-chunk (quadratic) ---
    # L[i,j] = exp(cum_i - cum_j) for j <= i else 0
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [b,nc,i,j,nh]
    tril = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: exp of +large on the masked side would be inf and
    # poison gradients through the where (0 * inf = nan under autodiff)
    diff = jnp.where(tril[None, None, :, :, None], diff, -1e30)
    L = jnp.exp(diff)
    cb = jnp.einsum("bnid,bnjd->bnij", Cc, Bc)             # [b,nc,i,j]
    att = cb[..., None] * L                                # [b,nc,i,j,nh]
    xdt = xc * dtc[..., None]                              # [b,nc,j,nh,hd]
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", att, xdt)

    # --- chunk summary states ---
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)        # [b,nc,j,nh]
    # S_n = sum_j decay_to_end_j * dt_j * B_j ⊗ x_j : [b,nc,nh,hd,ds]
    states = jnp.einsum("bnjh,bnjhp,bnjd->bnhpd",
                        decay_to_end * dtc, xc, Bc)

    # --- inter-chunk recurrence over nc chunks ---
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))             # [b,nc,nh]
    s0 = (jnp.zeros((b, nh, hd, ds), f32) if init_state is None
          else init_state.astype(f32))

    def step(carry, inp):
        st, dec = inp                                      # [b,nh,hd,ds],[b,nh]
        new = carry * dec[:, :, None, None] + st
        return new, carry                                  # emit state BEFORE chunk

    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # [b,nc,nh,hd,ds]

    # --- inter-chunk contribution ---
    in_decay = jnp.exp(cum)                                # decay from chunk start
    y_inter = jnp.einsum("bnid,bnih,bnhpd->bnihp",
                         Cc, in_decay, prev_states)
    y = (y_intra + y_inter).reshape(b, s, nh, hd)
    return y, final


def mamba2_full(params, cfg, x, conv_state=None, ssm_state=None):
    """Full-sequence Mamba2. x: [B,S,D].

    Returns (out [B,S,D], (conv_state, ssm_state)).
    """
    s_cfg = cfg.ssm
    b, s, d = x.shape
    di = s_cfg.d_inner(d)
    nh = s_cfg.n_heads(d)
    hd = s_cfg.head_dim
    ds = s_cfg.d_state
    zxbcdt = jnp.dot(x, params["in_proj"])
    z, xbc, dt = _split_in_proj(cfg, zxbcdt)
    xbc, new_conv = _causal_conv_full(xbc, params["conv_w"], params["conv_b"],
                                      conv_state)
    xin = xbc[..., :di].reshape(b, s, nh, hd)
    Bm = xbc[..., di: di + ds]
    Cm = xbc[..., di + ds:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    # pad sequence to a chunk multiple if needed
    chunk = min(s_cfg.chunk_size, s)
    pad = (-s) % chunk
    if pad:
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        y, final = ssd_chunked(zpad(xin), zpad(dt), A, zpad(Bm), zpad(Cm),
                               chunk, ssm_state)
        y = y[:, :s]
    else:
        y, final = ssd_chunked(xin, dt, A, Bm, Cm, chunk, ssm_state)
    y = y + params["D"][None, None, :, None] * xin.astype(jnp.float32)
    y = y.astype(x.dtype).reshape(b, s, di)
    y = rmsnorm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return jnp.dot(y, params["out_proj"]), (new_conv, final)


def mamba2_decode(params, cfg, x, conv_state, ssm_state):
    """One-token recurrent step. x: [B,1,D].

    conv_state: [B, K-1, conv_ch]; ssm_state: [B,nh,hd,ds] f32.
    Returns (out [B,1,D], new_conv_state, new_ssm_state).
    """
    s_cfg = cfg.ssm
    b, s, d = x.shape
    assert s == 1
    di = s_cfg.d_inner(d)
    nh, hd, ds = s_cfg.n_heads(d), s_cfg.head_dim, s_cfg.d_state
    zxbcdt = jnp.dot(x, params["in_proj"])
    z, xbc, dt = _split_in_proj(cfg, zxbcdt)
    k = params["conv_w"].shape[0]
    window = jnp.concatenate([conv_state, xbc], axis=1)    # [B, K, C]
    y = jnp.einsum("bkc,kc->bc", window, params["conv_w"])
    xbc = jax.nn.silu(y + params["conv_b"])[:, None, :]
    new_conv = window[:, 1:, :]
    xin = xbc[..., :di].reshape(b, nh, hd).astype(jnp.float32)
    Bm = xbc[:, 0, di: di + ds].astype(jnp.float32)
    Cm = xbc[:, 0, di + ds:].astype(jnp.float32)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # [B,nh]
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dtv * A[None, :])                      # [B,nh]
    upd = jnp.einsum("bh,bhp,bd->bhpd", dtv, xin, Bm)
    new_state = ssm_state * decay[:, :, None, None] + upd
    yv = jnp.einsum("bhpd,bd->bhp", new_state, Cm)
    yv = yv + params["D"][None, :, None] * xin
    yv = yv.reshape(b, 1, di).astype(x.dtype)
    yv = rmsnorm(yv * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    return jnp.dot(yv, params["out_proj"]), new_conv, new_state
