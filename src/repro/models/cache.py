"""Decode caches: per-layer KV (full or ring-buffer window), MLA latent
cache, and SSM recurrent state.  A cache is a plain pytree:

{
  "pos":   [B] int32            # tokens generated so far (global position)
  "layers": [per-layer dict]    # kind-dependent
}

Layer kinds:
  attn  -> {"k": [B,L,kv,hd], "v": [B,L,kv,hd]}
  mla   -> {"ckv": [B,L,rank], "kpe": [B,L,rope_d]}
  ssm   -> {"conv": [B,K-1,conv_ch], "ssm": [B,nh,hd,ds] f32}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ATTN, SSM, ModelConfig


def cache_len(cfg: ModelConfig, max_len: int) -> int:
    """Per-layer KV length: sliding-window archs only keep the window."""
    if cfg.sliding_window:
        return min(max_len, cfg.sliding_window)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
               window: int = 0, quantized: bool = False):
    """window > 0 forces a ring-buffer of that size on attention layers
    (the StreamingLLM-style long-context serving mode).

    ``quantized``: int8 KV with per-(token, head) bf16 scales — halves the
    decode memory term (§Perf; vLLM-style kv-cache quantization adapted to
    the static-slot TPU layout)."""
    if dtype is None:
        dtype = jnp.dtype(cfg.dtype)
    L = cache_len(cfg, max_len)
    if window:
        L = min(L, window)
    layers = []
    for i, kind in enumerate(cfg.layer_pattern):
        if kind == SSM:
            s = cfg.ssm
            di = s.d_inner(cfg.d_model)
            layers.append({
                "conv": jnp.zeros((batch, s.d_conv - 1, di + 2 * s.d_state),
                                  dtype),
                "ssm": jnp.zeros((batch, s.n_heads(cfg.d_model), s.head_dim,
                                  s.d_state), jnp.float32),
            })
        elif cfg.mla is not None:
            m = cfg.mla
            layers.append({
                "ckv": jnp.zeros((batch, L, m.kv_lora_rank), dtype),
                "kpe": jnp.zeros((batch, L, m.qk_rope_head_dim), dtype),
            })
        else:
            if quantized:
                layers.append({
                    "k": jnp.zeros((batch, L, cfg.num_kv_heads,
                                    cfg.head_dim), jnp.int8),
                    "v": jnp.zeros((batch, L, cfg.num_kv_heads,
                                    cfg.head_dim), jnp.int8),
                    "k_scale": jnp.zeros((batch, L, cfg.num_kv_heads, 1),
                                         jnp.bfloat16),
                    "v_scale": jnp.zeros((batch, L, cfg.num_kv_heads, 1),
                                         jnp.bfloat16),
                })
            else:
                layers.append({
                    "k": jnp.zeros((batch, L, cfg.num_kv_heads,
                                    cfg.head_dim), dtype),
                    "v": jnp.zeros((batch, L, cfg.num_kv_heads,
                                    cfg.head_dim), dtype),
                })
    return {"pos": jnp.zeros((batch,), jnp.int32), "layers": layers}


def cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
               window: int = 0, quantized: bool = False):
    """ShapeDtypeStruct pytree mirroring ``init_cache`` (no allocation)."""
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, dtype, window, quantized))


def quantize_kv(x):
    """x: [..., hd] -> (int8 values, bf16 scale [..., 1])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def dequantize_kv(q, scale):
    return q.astype(jnp.bfloat16) * scale


def _ring_write(buf, vals):
    """Write a prefilled sequence into a ring buffer of length L, keeping the
    ring invariant ``token t lives at slot t % L``.

    buf: [B, L, ...]; vals: [B, S, ...] (tokens 0..S-1).
    """
    L = buf.shape[1]
    s = vals.shape[1]
    vals = vals.astype(buf.dtype)
    if s < L:
        return jax.lax.dynamic_update_slice(
            buf, vals, (0,) * buf.ndim)
    kept = vals[:, s - L:]              # tokens s-L .. s-1, in order
    return jnp.roll(kept, shift=s % L, axis=1)


def write_prefill(cache, layer_idx: int, kv_tuple, cfg: ModelConfig):
    """Write full-sequence K/V (or latent) produced by a prefill pass into
    the cache at positions [0, S)."""
    layer = cache["layers"][layer_idx]
    if "ssm" in layer:
        conv, ssm = kv_tuple
        layer = {"conv": conv.astype(layer["conv"].dtype), "ssm": ssm}
    elif "ckv" in layer:
        ckv, kpe = kv_tuple
        layer = {
            "ckv": _ring_write(layer["ckv"], ckv),
            "kpe": _ring_write(layer["kpe"], kpe),
        }
    else:
        k, v = kv_tuple
        if "k_scale" in layer:
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            layer = {
                "k": _ring_write(layer["k"], kq),
                "v": _ring_write(layer["v"], vq),
                "k_scale": _ring_write(layer["k_scale"], ks),
                "v_scale": _ring_write(layer["v_scale"], vs),
            }
        else:
            layer = {
                "k": _ring_write(layer["k"], k),
                "v": _ring_write(layer["v"], v),
            }
    cache["layers"][layer_idx] = layer
    return cache
