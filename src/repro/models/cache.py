"""Decode caches: per-layer KV (full or ring-buffer window), MLA latent
cache, and SSM recurrent state.  A cache is a plain pytree:

{
  "pos":   [B] int32            # tokens generated so far (global position)
  "layers": [per-layer dict]    # kind-dependent
}

Layer kinds:
  attn  -> {"k": [B,L,kv,hd], "v": [B,L,kv,hd]}
  mla   -> {"ckv": [B,L,rank], "kpe": [B,L,rope_d]}
  ssm   -> {"conv": [B,K-1,conv_ch], "ssm": [B,nh,hd,ds] f32}

Two layouts share those kinds:

* **dense** (``init_cache``): one contiguous ``[B, L, ...]`` buffer per
  layer — HBM is priced by worst-case length per slot.
* **paged** (``init_paged_cache``): one shared block pool
  ``[num_blocks, block_size, ...]`` per layer plus per-slot block tables
  ``[B, pages_per_slot]`` — HBM is priced by *live tokens* (vLLM-style
  PagedAttention adapted to the static-shape TPU engine).  Block 0 is a
  reserved **null page**: unallocated table entries point at it and
  padded prefill tokens scatter into it, so every gather/scatter stays
  in-bounds without host-side masking.  SSM recurrent state stays
  per-slot (it is O(1) in sequence length).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ATTN, SSM, ModelConfig


def cache_len(cfg: ModelConfig, max_len: int) -> int:
    """Per-layer KV length: sliding-window archs only keep the window."""
    if cfg.sliding_window:
        return min(max_len, cfg.sliding_window)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
               window: int = 0, quantized: bool = False):
    """window > 0 forces a ring-buffer of that size on attention layers
    (the StreamingLLM-style long-context serving mode).

    ``quantized``: int8 KV with per-(token, head) bf16 scales — halves the
    decode memory term (§Perf; vLLM-style kv-cache quantization adapted to
    the static-slot TPU layout)."""
    if dtype is None:
        dtype = jnp.dtype(cfg.dtype)
    L = cache_len(cfg, max_len)
    if window:
        L = min(L, window)
    layers = []
    for i, kind in enumerate(cfg.layer_pattern):
        if kind == SSM:
            s = cfg.ssm
            di = s.d_inner(cfg.d_model)
            layers.append({
                "conv": jnp.zeros((batch, s.d_conv - 1, di + 2 * s.d_state),
                                  dtype),
                "ssm": jnp.zeros((batch, s.n_heads(cfg.d_model), s.head_dim,
                                  s.d_state), jnp.float32),
            })
        elif cfg.mla is not None:
            m = cfg.mla
            layers.append({
                "ckv": jnp.zeros((batch, L, m.kv_lora_rank), dtype),
                "kpe": jnp.zeros((batch, L, m.qk_rope_head_dim), dtype),
            })
        else:
            if quantized:
                layers.append({
                    "k": jnp.zeros((batch, L, cfg.num_kv_heads,
                                    cfg.head_dim), jnp.int8),
                    "v": jnp.zeros((batch, L, cfg.num_kv_heads,
                                    cfg.head_dim), jnp.int8),
                    "k_scale": jnp.zeros((batch, L, cfg.num_kv_heads, 1),
                                         jnp.bfloat16),
                    "v_scale": jnp.zeros((batch, L, cfg.num_kv_heads, 1),
                                         jnp.bfloat16),
                })
            else:
                layers.append({
                    "k": jnp.zeros((batch, L, cfg.num_kv_heads,
                                    cfg.head_dim), dtype),
                    "v": jnp.zeros((batch, L, cfg.num_kv_heads,
                                    cfg.head_dim), dtype),
                })
    return {"pos": jnp.zeros((batch,), jnp.int32), "layers": layers}


def cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
               window: int = 0, quantized: bool = False):
    """ShapeDtypeStruct pytree mirroring ``init_cache`` (no allocation)."""
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, dtype, window, quantized))


def quantize_kv(x):
    """x: [..., hd] -> (int8 values, bf16 scale [..., 1])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def dequantize_kv(q, scale):
    return q.astype(jnp.bfloat16) * scale


# ================================================================== paged
NULL_PAGE = 0           # reserved block: scatter/gather target for dead slots


def paged_slot_len(cfg: ModelConfig, max_len: int, block_size: int,
                   window: int = 0) -> int:
    """Logical per-slot ring length, rounded up to whole blocks."""
    L = cache_len(cfg, max_len)
    if window:
        L = min(L, window)
    return -(-L // block_size) * block_size


def init_paged_cache(cfg: ModelConfig, max_slots: int, max_len: int,
                     num_blocks: int, block_size: int = 16, dtype=None,
                     window: int = 0, quantized: bool = False):
    """Block-paged KV pool shared by ``max_slots`` request slots.

    {
      "pos":          [B] int32
      "block_tables": [B, pages_per_slot] int32   # 0 == null page
      "layers":       [per-layer dict]
    }

    attn -> {"k": [num_blocks, block_size, kv, hd], "v": ...}
            (+ "k_scale"/"v_scale" [num_blocks, block_size, kv, 1] when
            ``quantized``)
    mla  -> {"ckv": [num_blocks, block_size, rank], "kpe": [..., rope_d]}
    ssm  -> per-slot, identical to the dense layout.
    """
    if dtype is None:
        dtype = jnp.dtype(cfg.dtype)
    if num_blocks < 2:
        raise ValueError("num_blocks must be >= 2 (block 0 is the null page)")
    P = block_size
    layers = []
    for kind in cfg.layer_pattern:
        if kind == SSM:
            s = cfg.ssm
            di = s.d_inner(cfg.d_model)
            layers.append({
                "conv": jnp.zeros((max_slots, s.d_conv - 1,
                                   di + 2 * s.d_state), dtype),
                "ssm": jnp.zeros((max_slots, s.n_heads(cfg.d_model),
                                  s.head_dim, s.d_state), jnp.float32),
            })
        elif cfg.mla is not None:
            m = cfg.mla
            layers.append({
                "ckv": jnp.zeros((num_blocks, P, m.kv_lora_rank), dtype),
                "kpe": jnp.zeros((num_blocks, P, m.qk_rope_head_dim), dtype),
            })
        else:
            kv, hd = cfg.num_kv_heads, cfg.head_dim
            if quantized:
                layers.append({
                    "k": jnp.zeros((num_blocks, P, kv, hd), jnp.int8),
                    "v": jnp.zeros((num_blocks, P, kv, hd), jnp.int8),
                    "k_scale": jnp.zeros((num_blocks, P, kv, 1),
                                         jnp.bfloat16),
                    "v_scale": jnp.zeros((num_blocks, P, kv, 1),
                                         jnp.bfloat16),
                })
            else:
                layers.append({
                    "k": jnp.zeros((num_blocks, P, kv, hd), dtype),
                    "v": jnp.zeros((num_blocks, P, kv, hd), dtype),
                })
    pages_per_slot = paged_slot_len(cfg, max_len, P, window) // P
    return {"pos": jnp.zeros((max_slots,), jnp.int32),
            "block_tables": jnp.zeros((max_slots, pages_per_slot),
                                      jnp.int32),
            "layers": layers}


def paged_token_write(pages, vals, page_ids, offs):
    """Scatter one token per sequence into the pool.

    pages: [N, P, ...]; vals: [B, ...]; page_ids/offs: [B] int32.
    O(B) — independent of pool size (and in-place under jit donation)."""
    return pages.at[page_ids, offs].set(vals.astype(pages.dtype))


def paged_prefill_write(pages, vals, block_table, n, start=0):
    """Scatter a prefilled span of ONE slot into its pages.

    pages: [N, P, ...]; vals: [S, ...] (first ``n`` rows valid — the rest
    are padding); block_table: [pages_per_slot] int32; positions are
    ``start .. start+S-1`` on the slot's logical ring of length
    ``pages_per_slot * P``.  Padding rows and ring-evicted rows (when the
    span wraps) are routed to the null page, so duplicate in-bound
    indices never race.  O(S) — no O(pool) commit copy."""
    S = vals.shape[0]
    P = pages.shape[1]
    L = block_table.shape[0] * P
    p = start + jnp.arange(S, dtype=jnp.int32)
    end = start + n
    keep = (p < end) & (p >= end - L)
    widx = jnp.mod(p, L)
    page_ids = jnp.where(keep, block_table[widx // P], NULL_PAGE)
    return pages.at[page_ids, jnp.mod(widx, P)].set(vals.astype(pages.dtype))


def write_prefill_paged(cache, layer_idx: int, kv_tuple, cfg: ModelConfig,
                        slot, n):
    """Paged counterpart of :func:`write_prefill`: write one request's
    full-sequence K/V (or latent / SSM state) produced by a prefill pass
    into ``slot``'s pages at positions [0, n).  ``kv_tuple`` entries are
    [1, S, ...] (S >= n; tail is padding)."""
    layer = cache["layers"][layer_idx]
    bt = cache["block_tables"][slot]
    if "ssm" in layer:
        conv, ssm = kv_tuple
        layer = {"conv": layer["conv"].at[slot].set(
                     conv[0].astype(layer["conv"].dtype)),
                 "ssm": layer["ssm"].at[slot].set(ssm[0])}
    elif "ckv" in layer:
        ckv, kpe = kv_tuple
        layer = {
            "ckv": paged_prefill_write(layer["ckv"], ckv[0], bt, n),
            "kpe": paged_prefill_write(layer["kpe"], kpe[0], bt, n),
        }
    else:
        k, v = kv_tuple
        if "k_scale" in layer:
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            layer = {
                "k": paged_prefill_write(layer["k"], kq[0], bt, n),
                "v": paged_prefill_write(layer["v"], vq[0], bt, n),
                "k_scale": paged_prefill_write(layer["k_scale"], ks[0],
                                               bt, n),
                "v_scale": paged_prefill_write(layer["v_scale"], vs[0],
                                               bt, n),
            }
        else:
            layer = {
                "k": paged_prefill_write(layer["k"], k[0], bt, n),
                "v": paged_prefill_write(layer["v"], v[0], bt, n),
            }
    cache["layers"][layer_idx] = layer
    return cache


def copy_page(cache, src: int, dst: int):
    """Copy one KV page across every paged layer: the device half of
    copy-on-write.  A writer about to touch a block other owners still
    share gets a private copy at ``dst`` first (host side: fresh alloc +
    block-table patch in the engine).  Per-slot state (SSM) is untouched
    — it is never shared."""
    for i, layer in enumerate(cache["layers"]):
        if "conv" in layer:
            continue
        cache["layers"][i] = {k: v.at[dst].set(v[src])
                              for k, v in layer.items()}
    return cache


def gather_pages(pages, block_tables):
    """Materialize the logical [B, L, ...] view of a paged layer.

    pages: [N, P, ...]; block_tables: [B, pages_per_slot].  Gathers live
    pages only — the XLA fallback for the Pallas paged-decode kernel and
    the chunked-prefill prefix read."""
    b, npg = block_tables.shape
    g = pages[block_tables]                     # [B, pages, P, ...]
    return g.reshape((b, npg * pages.shape[1]) + pages.shape[2:])


def kv_bytes_per_token(cfg: ModelConfig, dtype=None,
                       quantized: bool = False) -> int:
    """HBM bytes of KV (or MLA latent) cache per token, across layers.
    SSM layers contribute 0 (their state is O(1) in sequence length)."""
    if dtype is None:
        dtype = jnp.dtype(cfg.dtype)
    size = jnp.dtype(dtype).itemsize
    total = 0
    for kind in cfg.layer_pattern:
        if kind == SSM:
            continue
        if cfg.mla is not None:
            total += (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) * size
        elif quantized:
            # int8 values + one bf16 scale per (token, head) for k and v
            total += 2 * cfg.num_kv_heads * (cfg.head_dim * 1 + 2)
        else:
            total += 2 * cfg.num_kv_heads * cfg.head_dim * size
    return total


def _ring_write(buf, vals):
    """Write a prefilled sequence into a ring buffer of length L, keeping the
    ring invariant ``token t lives at slot t % L``.

    buf: [B, L, ...]; vals: [B, S, ...] (tokens 0..S-1).
    """
    L = buf.shape[1]
    s = vals.shape[1]
    vals = vals.astype(buf.dtype)
    if s < L:
        return jax.lax.dynamic_update_slice(
            buf, vals, (0,) * buf.ndim)
    kept = vals[:, s - L:]              # tokens s-L .. s-1, in order
    return jnp.roll(kept, shift=s % L, axis=1)


def write_prefill(cache, layer_idx: int, kv_tuple, cfg: ModelConfig):
    """Write full-sequence K/V (or latent) produced by a prefill pass into
    the cache at positions [0, S)."""
    layer = cache["layers"][layer_idx]
    if "ssm" in layer:
        conv, ssm = kv_tuple
        layer = {"conv": conv.astype(layer["conv"].dtype), "ssm": ssm}
    elif "ckv" in layer:
        ckv, kpe = kv_tuple
        layer = {
            "ckv": _ring_write(layer["ckv"], ckv),
            "kpe": _ring_write(layer["kpe"], kpe),
        }
    else:
        k, v = kv_tuple
        if "k_scale" in layer:
            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            layer = {
                "k": _ring_write(layer["k"], kq),
                "v": _ring_write(layer["v"], vq),
                "k_scale": _ring_write(layer["k_scale"], ks),
                "v_scale": _ring_write(layer["v_scale"], vs),
            }
        else:
            layer = {
                "k": _ring_write(layer["k"], k),
                "v": _ring_write(layer["v"], v),
            }
    cache["layers"][layer_idx] = layer
    return cache
