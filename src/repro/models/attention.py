"""Attention blocks: GQA (RoPE / M-RoPE, qk-norm, sliding window) and MLA.

Two execution paths per block:
  * ``full``  — prefill / train over a whole sequence with a causal
    (optionally sliding-window) mask; optionally writes a KV cache.
  * ``decode`` — a single new token attending to a cache.

MLA (DeepSeek-V2) uses the compressed-KV cache with the *absorbed* decode
formulation: scores are computed directly against the latent cache, so the
per-token decode cost is O(L · (kv_lora + rope_dim)) instead of
O(L · heads · head_dim).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, rmsnorm, rope_cos_sin

NEG_INF = -1e30


def _constrain(x, ctx, *spec):
    """with_sharding_constraint when a mesh ctx is present (no-op otherwise)."""
    if ctx is None or getattr(ctx, "mesh", None) is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


_USE_KERNELS = False


def set_attention_kernels(enabled: bool):
    """Route full-sequence attention through the Pallas flash kernel
    (compiled on TPU; interpret/ref on CPU via kernels.ops mode)."""
    global _USE_KERNELS
    _USE_KERNELS = enabled


def _use_attn_kernel(cfg, s: int) -> bool:
    if not _USE_KERNELS or cfg.attn_logit_softcap:
        return False
    return s >= 16 and s % 16 == 0


def _seq_parallel_wanted(cfg, ctx, s: int) -> bool:
    if ctx is None or getattr(ctx, "mesh", None) is None:
        return False
    if getattr(ctx, "attn_sharding", "none") != "auto" or s <= 1:
        return False
    return s % ctx.mesh.shape[ctx.tp_axis] == 0


# =================================================================== GQA
def init_gqa(key, cfg, dtype):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, h * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, kv * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, kv * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (h * hd, d)) * (h * hd) ** -0.5).astype(dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _expand_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """[B,S,kv,hd] -> [B,S,kv*n_rep,hd]."""
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)
                            ).reshape(b, s, kv * n_rep, hd)


def _causal_mask(q_len: int, kv_len: int, q_offset, window: int) -> jax.Array:
    """[q_len, kv_len] bool; True = attend. q position i sits at q_offset+i."""
    qpos = q_offset + jnp.arange(q_len)[:, None]
    kpos = jnp.arange(kv_len)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m


def gqa_full(params, cfg, x, positions, *, cache_len: int = 0, ctx=None):
    """Full-sequence attention.

    x: [B, S, D]; positions: [B, S] (or [B, S, 3] for M-RoPE).
    Returns (out [B,S,D], (k, v) [B,S,kv,hd] for cache writing).

    With ``ctx.attn_sharding == "auto"``, sequence-parallel constraints are
    applied: q (and the scores' q dim) shard over the tp axis while k/v are
    replicated within the tp group — correct for ANY head count, unlike
    head sharding which needs h % tp == 0 (§Perf: fixes the giant score
    all-reduces GSPMD emits for h=24/28 archs).
    """
    b, s, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.dot(x, params["wq"]).reshape(b, s, h, hd)
    k = jnp.dot(x, params["wk"]).reshape(b, s, kv, hd)
    v = jnp.dot(x, params["wv"]).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta, cfg.mrope_sections)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    seq_par = _seq_parallel_wanted(cfg, ctx, s)
    if seq_par:
        tp = ctx.tp_axis
        dpb = ctx.dp_axes if b % _axes_prod(ctx) == 0 else None
        q = _constrain(q, ctx, dpb, tp, None, None)
        k = _constrain(k, ctx, dpb, None, None, None)
        v = _constrain(v, ctx, dpb, None, None, None)
    if not seq_par and _use_attn_kernel(cfg, s):
        # Pallas flash-attention path (TPU compiled / CPU interpret)
        from repro.kernels import ops
        out = ops.flash_attention(q, k, v, causal=True,
                                  window=cfg.sliding_window)
        out = out.reshape(b, s, h * hd)
        return jnp.dot(out, params["wo"]), (k, v)
    rep = h // kv
    qg = q.reshape(b, s, kv, rep, hd)
    mask = _causal_mask(s, s, 0, cfg.sliding_window)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k) \
        / jnp.sqrt(hd).astype(x.dtype)
    if seq_par:
        scores = _constrain(scores, ctx, dpb, None, None, ctx.tp_axis, None)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        scores = c * jnp.tanh(scores / c)
    scores = jnp.where(mask[None, None, None], scores.astype(jnp.float32),
                       NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v).reshape(b, s, h * hd)
    if seq_par:
        out = _constrain(out, ctx, dpb, ctx.tp_axis, None)
    return jnp.dot(out, params["wo"]), (k, v)


def _axes_prod(ctx) -> int:
    n = 1
    for a in ctx.dp_axes:
        n *= ctx.mesh.shape[a]
    return n


def _ring_token_write(cache, val, widx):
    return jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u, (i,) + (0,) * (c.ndim - 1)))(cache, val.astype(cache.dtype),
                                           widx)


def gqa_decode(params, cfg, x, positions, k_cache, v_cache, cache_pos,
               k_scale=None, v_scale=None):
    """One-token decode against a cache.

    x: [B, 1, D]; k_cache/v_cache: [B, L, kv, hd]; cache_pos: [B] int32 —
    number of valid tokens already in the cache.  Returns
    (out [B,1,D], new cache entries dict).
    For sliding-window configs the cache is a ring buffer of length
    min(L, window) and positions wrap.  With int8 caches (k_scale given)
    the new token is quantized per (token, head) and the cache is
    dequantized inside the score/value contractions (fused on TPU).
    """
    from repro.models.cache import dequantize_kv, quantize_kv
    b, s, d = x.shape
    assert s == 1
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    L = k_cache.shape[1]
    q = jnp.dot(x, params["wq"]).reshape(b, 1, h, hd)
    k = jnp.dot(x, params["wk"]).reshape(b, 1, kv, hd)
    v = jnp.dot(x, params["wv"]).reshape(b, 1, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta, cfg.mrope_sections)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    # ring-buffer write index
    widx = jnp.mod(cache_pos, L)  # [B]
    quant = k_scale is not None
    if quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k_cache = _ring_token_write(k_cache, kq, widx)
        v_cache = _ring_token_write(v_cache, vq, widx)
        k_scale = _ring_token_write(k_scale, ks, widx)
        v_scale = _ring_token_write(v_scale, vs, widx)
        k_eff = dequantize_kv(k_cache, k_scale)
        v_eff = dequantize_kv(v_cache, v_scale)
    else:
        k_cache = _ring_token_write(k_cache, k, widx)
        v_cache = _ring_token_write(v_cache, v, widx)
        k_eff, v_eff = k_cache, v_cache
    n_valid = jnp.minimum(cache_pos + 1, L)  # [B]
    # grouped-GQA form: never materialize the head-expanded cache — the
    # cache keeps its (possibly sequence-sharded) layout and the partitioner
    # reduces over the sharded L dim with small collectives.
    rep = h // kv
    qg = q.reshape(b, kv, rep, hd)  # [B,kv,rep,hd]
    scores = jnp.einsum("bgrd,blgd->bgrl", qg,
                        k_eff.astype(qg.dtype)) \
        / jnp.sqrt(hd).astype(x.dtype)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        scores = c * jnp.tanh(scores / c)
    valid = jnp.arange(L)[None, :] < n_valid[:, None]  # [B, L]
    scores = jnp.where(valid[:, None, None, :], scores.astype(jnp.float32),
                       NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bgrl,blgd->bgrd", probs,
                     v_eff.astype(probs.dtype)).reshape(b, 1, h * hd)
    new_cache = {"k": k_cache, "v": v_cache}
    if quant:
        new_cache.update(k_scale=k_scale, v_scale=v_scale)
    return jnp.dot(out, params["wo"]), new_cache


# =================================================================== MLA
def init_mla(key, cfg, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    r = m.kv_lora_rank ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], (d, h * qk_hd)) * s).astype(dtype),
        "w_dkv": (jax.random.normal(ks[1], (d, m.kv_lora_rank)) * s).astype(dtype),
        "w_krope": (jax.random.normal(ks[2], (d, m.qk_rope_head_dim)) * s).astype(dtype),
        "w_uk": (jax.random.normal(ks[3], (m.kv_lora_rank, h * m.qk_nope_head_dim)) * r).astype(dtype),
        "w_uv": (jax.random.normal(ks[4], (m.kv_lora_rank, h * m.v_head_dim)) * r).astype(dtype),
        "wo": (jax.random.normal(ks[5], (h * m.v_head_dim, d))
               * (h * m.v_head_dim) ** -0.5).astype(dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
    }


def mla_full(params, cfg, x, positions, **_):
    """MLA prefill/train: expand the latent and run standard attention.

    Returns (out, (c_kv, k_rope)) for cache writing.
    """
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.num_heads
    nope, rope_d, vhd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q = jnp.dot(x, params["wq"]).reshape(b, s, h, nope + rope_d)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    c_kv = rmsnorm(jnp.dot(x, params["w_dkv"]), params["kv_norm"], cfg.norm_eps)
    k_pe = jnp.dot(x, params["w_krope"]).reshape(b, s, 1, rope_d)
    cos, sin = rope_cos_sin(positions, rope_d, cfg.rope_theta)
    q_pe = apply_rope(q_pe, cos, sin)
    k_pe = apply_rope(k_pe, cos, sin)
    k_nope = jnp.dot(c_kv, params["w_uk"]).reshape(b, s, h, nope)
    v = jnp.dot(c_kv, params["w_uv"]).reshape(b, s, h, vhd)
    scale = 1.0 / jnp.sqrt(nope + rope_d).astype(x.dtype)
    scores = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope)
              + jnp.einsum("bqhd,bkhd->bhqk", q_pe,
                           jnp.broadcast_to(k_pe, (b, s, h, rope_d)))) * scale
    mask = _causal_mask(s, s, 0, 0)
    scores = jnp.where(mask[None, None], scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, s, h * vhd)
    return jnp.dot(out, params["wo"]), (c_kv, k_pe[:, :, 0, :])


def mla_decode(params, cfg, x, positions, ckv_cache, kpe_cache, cache_pos):
    """Absorbed MLA decode: attend in the latent space.

    ckv_cache: [B, L, kv_lora]; kpe_cache: [B, L, rope_d].
    score_l = q_nope_h · W_uk_h · c_l + q_pe_h · kpe_l
    out_h   = (Σ p_l c_l) · W_uv_h
    """
    m = cfg.mla
    b, s, d = x.shape
    assert s == 1
    h = cfg.num_heads
    nope, rope_d, vhd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    rank = m.kv_lora_rank
    L = ckv_cache.shape[1]
    q = jnp.dot(x, params["wq"]).reshape(b, 1, h, nope + rope_d)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    c_kv = rmsnorm(jnp.dot(x, params["w_dkv"]), params["kv_norm"], cfg.norm_eps)  # [B,1,rank]
    k_pe = jnp.dot(x, params["w_krope"]).reshape(b, 1, 1, rope_d)
    cos, sin = rope_cos_sin(positions, rope_d, cfg.rope_theta)
    q_pe = apply_rope(q_pe, cos, sin)
    k_pe = apply_rope(k_pe, cos, sin)[:, :, 0, :]  # [B,1,rope_d]
    widx = jnp.mod(cache_pos, L)
    ckv_cache = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u, (i, 0)))(ckv_cache, c_kv.astype(ckv_cache.dtype), widx)
    kpe_cache = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u, (i, 0)))(kpe_cache, k_pe.astype(kpe_cache.dtype), widx)
    n_valid = jnp.minimum(cache_pos + 1, L)
    # absorb W_uk into q:  q_abs [B,h,rank]
    w_uk = params["w_uk"].reshape(rank, h, nope)
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
    scale = 1.0 / jnp.sqrt(nope + rope_d)
    scores = (jnp.einsum("bhr,blr->bhl", q_abs.astype(jnp.float32),
                         ckv_cache.astype(jnp.float32))
              + jnp.einsum("bhd,bld->bhl", q_pe[:, 0].astype(jnp.float32),
                           kpe_cache.astype(jnp.float32))) * scale
    valid = jnp.arange(L)[None, :] < n_valid[:, None]
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhl,blr->bhr", probs,
                     ckv_cache.astype(jnp.float32)).astype(x.dtype)  # [B,h,rank]
    w_uv = params["w_uv"].reshape(rank, h, vhd)
    out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv).reshape(b, 1, h * vhd)
    return jnp.dot(out, params["wo"]), ckv_cache, kpe_cache


def _chunk_attend(params, cfg, x, positions, k_prefix, v_prefix, start_pos):
    """Shared chunk-continuation attention over a materialized prefix.

    A chunk of C tokens at absolute positions [start_pos, start_pos+C)
    attends to the cached prefix (ring, token-id masked) plus itself
    (intra-chunk causal).  The caller writes the chunk's K/V into its
    cache layout (dense ring or paged pool) afterwards, so in-chunk
    evictions cannot clobber keys still needed by earlier queries.

    x: [B, C, D]; k_prefix/v_prefix: [B, L, kv, hd] (the logical cache
    view); start_pos: int/traced.  Returns (out [B,C,D], k_chunk,
    v_chunk [B,C,kv,hd]).
    """
    b, c, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    L = k_prefix.shape[1]
    assert c <= L, "chunk larger than the cache ring"
    q = jnp.dot(x, params["wq"]).reshape(b, c, h, hd)
    k = jnp.dot(x, params["wk"]).reshape(b, c, kv, hd)
    v = jnp.dot(x, params["wv"]).reshape(b, c, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta, cfg.mrope_sections)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    sp = jnp.asarray(start_pos, jnp.int32)
    window = cfg.sliding_window
    rep = h // kv
    qg = q.reshape(b, c, kv, rep, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(x.dtype)
    qpos = sp + jnp.arange(c, dtype=jnp.int32)[:, None]        # [c,1]

    # ---- part 1: cached prefix (tokens < sp), ring token-id masking
    slots = jnp.arange(L, dtype=jnp.int32)[None, :]            # [1,L]
    # largest token id t == slot (mod L) with t < sp
    t_slot = sp - 1 - jnp.mod(sp - 1 - slots, L)               # [1,L]
    m_pre = (t_slot >= 0) & (t_slot <= qpos)
    if window > 0:
        m_pre &= t_slot > qpos - window
    s_pre = jnp.einsum("bqgrd,blgd->bgrql", qg,
                       k_prefix.astype(qg.dtype)) * scale
    s_pre = jnp.where(m_pre[None, None, None], s_pre.astype(jnp.float32),
                      NEG_INF)

    # ---- part 2: the fresh chunk, intra-chunk causal
    cpos = sp + jnp.arange(c, dtype=jnp.int32)[None, :]        # [1,c]
    m_chk = cpos <= qpos
    if window > 0:
        m_chk &= cpos > qpos - window
    s_chk = jnp.einsum("bqgrd,bcgd->bgrqc", qg, k) * scale
    s_chk = jnp.where(m_chk[None, None, None], s_chk.astype(jnp.float32),
                      NEG_INF)

    scores = jnp.concatenate([s_pre, s_chk], axis=-1)          # [b,g,r,q,L+c]
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bgrql,blgd->bqgrd", probs[..., :L],
                     v_prefix.astype(probs.dtype)) + \
        jnp.einsum("bgrqc,bcgd->bqgrd", probs[..., L:], v)
    out = out.reshape(b, c, h * hd)
    return jnp.dot(out, params["wo"]), k, v


def gqa_continue(params, cfg, x, positions, k_cache, v_cache, start_pos):
    """Chunked-prefill continuation (Sarathi-style) on the dense layout.

    Ring-safe: the cache may be a window ring (slot t%L holds token t).
    x: [B, C, D]; k_cache/v_cache: [B, L, kv, hd]; start_pos: int/traced.
    Returns (out [B,C,D], new_k_cache, new_v_cache).
    """
    L = k_cache.shape[1]
    c = x.shape[1]
    out, k, v = _chunk_attend(params, cfg, x, positions, k_cache, v_cache,
                              start_pos)
    # ---- deferred ring write of the chunk
    sp = jnp.asarray(start_pos, jnp.int32)
    widx = jnp.mod(sp + jnp.arange(c, dtype=jnp.int32), L)
    k_cache = k_cache.at[:, widx].set(k.astype(k_cache.dtype))
    v_cache = v_cache.at[:, widx].set(v.astype(v_cache.dtype))
    return out, k_cache, v_cache


# ============================================================== paged GQA
def _paged_write_token(layer_cache, k, v, block_tables, cache_pos,
                       quantized: bool):
    """Write one new token per sequence into its page (O(B) scatter).

    k/v: [B, 1, kv, hd]; returns the updated layer dict."""
    from repro.models.cache import paged_token_write, quantize_kv
    P = layer_cache["k"].shape[1]
    L = block_tables.shape[1] * P
    widx = jnp.mod(cache_pos, L)                              # [B]
    page_ids = jnp.take_along_axis(block_tables, (widx // P)[:, None],
                                   axis=1)[:, 0]
    offs = jnp.mod(widx, P)
    if quantized:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        return {
            "k": paged_token_write(layer_cache["k"], kq[:, 0], page_ids, offs),
            "v": paged_token_write(layer_cache["v"], vq[:, 0], page_ids, offs),
            "k_scale": paged_token_write(layer_cache["k_scale"], ks[:, 0],
                                         page_ids, offs),
            "v_scale": paged_token_write(layer_cache["v_scale"], vs[:, 0],
                                         page_ids, offs),
        }
    return {
        "k": paged_token_write(layer_cache["k"], k[:, 0], page_ids, offs),
        "v": paged_token_write(layer_cache["v"], v[:, 0], page_ids, offs),
    }


def gqa_decode_paged(params, cfg, x, positions, layer_cache, block_tables,
                     cache_pos):
    """One-token decode against the paged KV pool.

    x: [B, 1, D]; layer_cache: {"k","v"[,"k_scale","v_scale"]} page
    arrays [N, P, kv, hd]; block_tables: [B, pages_per_slot] int32;
    cache_pos: [B] int32.  The new token is written in place into its
    page (O(B), not O(pool)), then attention runs through the block
    table — the Pallas paged flash-decode kernel on TPU, the gather
    reference on CPU (``kernels.ops`` dispatch).
    Returns (out [B,1,D], new layer dict).
    """
    from repro.kernels import ops
    from repro.models.cache import dequantize_kv, gather_pages
    b, s, d = x.shape
    assert s == 1
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.dot(x, params["wq"]).reshape(b, 1, h, hd)
    k = jnp.dot(x, params["wk"]).reshape(b, 1, kv, hd)
    v = jnp.dot(x, params["wv"]).reshape(b, 1, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta, cfg.mrope_sections)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    quant = "k_scale" in layer_cache
    new_cache = _paged_write_token(layer_cache, k, v, block_tables,
                                   cache_pos, quant)
    lengths = cache_pos + 1
    if cfg.attn_logit_softcap:
        # the paged kernel (like the dense one) has no logit softcap —
        # gather the live pages and run the einsum path
        P = new_cache["k"].shape[1]
        L = block_tables.shape[1] * P
        k_eff = gather_pages(new_cache["k"], block_tables)
        v_eff = gather_pages(new_cache["v"], block_tables)
        if quant:
            k_eff = dequantize_kv(k_eff, gather_pages(new_cache["k_scale"],
                                                      block_tables))
            v_eff = dequantize_kv(v_eff, gather_pages(new_cache["v_scale"],
                                                      block_tables))
        rep = h // kv
        qg = q.reshape(b, kv, rep, hd)
        scores = jnp.einsum("bgrd,blgd->bgrl", qg,
                            k_eff.astype(qg.dtype)) \
            / jnp.sqrt(hd).astype(x.dtype)
        c = cfg.attn_logit_softcap
        scores = c * jnp.tanh(scores / c)
        ln = lengths[:, None]
        s_idx = jnp.arange(L, dtype=jnp.int32)[None, :]
        t_s = ln - 1 - jnp.mod(ln - 1 - s_idx, L)
        valid = t_s >= 0
        if cfg.sliding_window > 0:
            valid &= t_s > ln - 1 - cfg.sliding_window
        scores = jnp.where(valid[:, None, None, :],
                           scores.astype(jnp.float32), NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o = jnp.einsum("bgrl,blgd->bgrd", probs,
                       v_eff.astype(probs.dtype)).reshape(b, h, hd)
    elif quant:
        o = ops.decode_attention_paged_q8(
            q[:, 0], new_cache["k"], new_cache["k_scale"], new_cache["v"],
            new_cache["v_scale"], block_tables, lengths,
            window=cfg.sliding_window)
    else:
        o = ops.decode_attention_paged(
            q[:, 0], new_cache["k"], new_cache["v"], block_tables, lengths,
            window=cfg.sliding_window)
    out = o.astype(x.dtype).reshape(b, 1, h * hd)
    return jnp.dot(out, params["wo"]), new_cache


def gqa_continue_paged(params, cfg, x, positions, layer_cache, block_tables,
                       start_pos, n=None):
    """Chunked-prefill continuation on the paged pool (single slot).

    x: [B, C, D] (B = 1 slot); the prefix is gathered through the block
    table (dequantized for int8 caches), the chunk is scattered into its
    pages afterwards (O(C); quantized with fresh per-token scales).
    ``n`` (static or traced; default C) is the number of *valid* chunk
    rows — padded suffix-prefill buckets write only their valid span,
    while padded keys beyond it stay causally masked out of every valid
    query anyway.  Returns (out [B,C,D], new layer dict).
    """
    from repro.models.cache import (dequantize_kv, gather_pages,
                                    paged_prefill_write, quantize_kv)
    c = x.shape[1]
    if n is None:
        n = c
    quant = "k_scale" in layer_cache
    k_prefix = gather_pages(layer_cache["k"], block_tables)
    v_prefix = gather_pages(layer_cache["v"], block_tables)
    if quant:
        k_prefix = dequantize_kv(k_prefix,
                                 gather_pages(layer_cache["k_scale"],
                                              block_tables))
        v_prefix = dequantize_kv(v_prefix,
                                 gather_pages(layer_cache["v_scale"],
                                              block_tables))
    out, k, v = _chunk_attend(params, cfg, x, positions, k_prefix, v_prefix,
                              start_pos)
    bt = block_tables[0]

    def write(pages, vals):
        return paged_prefill_write(pages, vals[0], bt, n, start=start_pos)
    if quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        return out, {"k": write(layer_cache["k"], kq),
                     "v": write(layer_cache["v"], vq),
                     "k_scale": write(layer_cache["k_scale"], ks),
                     "v_scale": write(layer_cache["v_scale"], vs)}
    return out, {"k": write(layer_cache["k"], k),
                 "v": write(layer_cache["v"], v)}


def mla_decode_paged(params, cfg, x, positions, ckv_pages, kpe_pages,
                     block_tables, cache_pos):
    """Absorbed MLA decode against paged latent caches.

    The new latent token is written in place into its page, then the
    live pages are gathered into the logical [B, L, rank] view and the
    dense absorbed-decode math runs on it (the latent is too narrow for
    a per-kv-head kernel tile; capacity, not decode reads, is what
    paging buys MLA archs).  Returns (out, new_ckv_pages, new_kpe_pages).
    """
    from repro.models.cache import gather_pages, paged_token_write
    m = cfg.mla
    b, s, d = x.shape
    assert s == 1
    h = cfg.num_heads
    nope, rope_d, vhd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    rank = m.kv_lora_rank
    P = ckv_pages.shape[1]
    L = block_tables.shape[1] * P
    q = jnp.dot(x, params["wq"]).reshape(b, 1, h, nope + rope_d)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    c_kv = rmsnorm(jnp.dot(x, params["w_dkv"]), params["kv_norm"],
                   cfg.norm_eps)                              # [B,1,rank]
    k_pe = jnp.dot(x, params["w_krope"]).reshape(b, 1, 1, rope_d)
    cos, sin = rope_cos_sin(positions, rope_d, cfg.rope_theta)
    q_pe = apply_rope(q_pe, cos, sin)
    k_pe = apply_rope(k_pe, cos, sin)[:, :, 0, :]             # [B,1,rope_d]
    widx = jnp.mod(cache_pos, L)
    page_ids = jnp.take_along_axis(block_tables, (widx // P)[:, None],
                                   axis=1)[:, 0]
    offs = jnp.mod(widx, P)
    ckv_pages = paged_token_write(ckv_pages, c_kv[:, 0], page_ids, offs)
    kpe_pages = paged_token_write(kpe_pages, k_pe[:, 0], page_ids, offs)
    ckv = gather_pages(ckv_pages, block_tables)               # [B,L,rank]
    kpe = gather_pages(kpe_pages, block_tables)               # [B,L,rope_d]
    n_valid = jnp.minimum(cache_pos + 1, L)
    w_uk = params["w_uk"].reshape(rank, h, nope)
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
    scale = 1.0 / jnp.sqrt(nope + rope_d)
    scores = (jnp.einsum("bhr,blr->bhl", q_abs.astype(jnp.float32),
                         ckv.astype(jnp.float32))
              + jnp.einsum("bhd,bld->bhl", q_pe[:, 0].astype(jnp.float32),
                           kpe.astype(jnp.float32))) * scale
    valid = jnp.arange(L)[None, :] < n_valid[:, None]
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhl,blr->bhr", probs,
                     ckv.astype(jnp.float32)).astype(x.dtype)
    w_uv = params["w_uv"].reshape(rank, h, vhd)
    out = jnp.einsum("bhr,rhd->bhd", ctx, w_uv).reshape(b, 1, h * vhd)
    return jnp.dot(out, params["wo"]), ckv_pages, kpe_pages
