"""Data-parallel serving: N engines behind one thread-safe front door.

:class:`EngineFleet` runs one :class:`~repro.serving.loop.ServeLoop` per
engine and routes arrivals through a registry
:class:`~repro.core.policies.InstanceMapper` — the same objects the
multi-instance scheduler (``SLOAwareScheduler.assign_instances``) and the
simulator's ``run_multi_instance`` use, so Algorithm 2's instance
assignment runs unchanged against real engines.

Two submission modes:

* **Online** — :meth:`submit` routes each arrival as it comes, against a
  live :class:`~repro.core.policies.InstanceState` snapshot of every
  loop (queue depth, occupied slots, KV-pool headroom).  This is the
  least-loaded / SLO-affinity regime.
* **Batch-planned** — :meth:`submit_trace` hands the whole trace to
  ``mapper.plan``: a planning mapper (``route:annealed``, the paper's
  Algorithm 2) both *assigns* requests to instances (memory-greedy,
  Eq. 20) and *orders* each instance's queue (the per-instance
  Algorithm-1 anneal).  The fleet submits in exactly that order; each
  loop's arrival-stable ingestion turns the plan into its FCFS
  admission order, so the annealed priority plan is what the engines
  actually execute.

Every loop gets a disjoint request-id range (``id_base``), so results,
streams and the aggregated :class:`~repro.serving.metrics.ServingMetrics`
share one namespace.  ``serve()`` drives all loops concurrently in
threads — the GIL interleaves host-side scheduling while each loop's
device work proceeds under its own dispatch chain.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence

from repro.core.latency_model import LinearLatencyModel
from repro.core.policies import InstanceState, make_mapper
from repro.core.slo import SLO, Request
from repro.engine.engine import Engine
from repro.serving.loop import ServeLoop
from repro.serving.metrics import ServingMetrics
from repro.serving.stream import TokenStream

# disjoint per-loop request-id ranges (see ServeLoop id_base)
_ID_STRIDE = 1_000_000


class EngineFleet:
    """N serving loops behind one submission queue.

    Parameters
    ----------
    engines:
        Fresh engines, one per instance (their pools become the fleet's
        capacity).  Engines may themselves be mesh-sharded (tensor
        parallel) — the two axes compose.
    policy / model / discipline / overlap / bucket_batches:
        Forwarded to every member :class:`ServeLoop`.
    mapper:
        :class:`~repro.core.policies.InstanceMapper` instance or
        registry key (``"least-loaded"`` default, ``"round-robin"``,
        ``"slo-affinity"``, ``"memory-greedy"``, ``"annealed"``) —
        mapper kwargs (``model=...``) ride through ``make_mapper``.
    """

    def __init__(self, engines: Sequence[Engine], policy="fcfs", *,
                 mapper="least-loaded",
                 model: Optional[LinearLatencyModel] = None,
                 discipline=None, overlap: bool = True,
                 bucket_batches: bool = True, **mapper_kw):
        if not engines:
            raise ValueError("EngineFleet needs at least one engine")
        self.loops: List[ServeLoop] = [
            ServeLoop(eng, policy, model=model, discipline=discipline,
                      overlap=overlap, bucket_batches=bucket_batches,
                      id_base=i * _ID_STRIDE)
            for i, eng in enumerate(engines)]
        self.mapper = make_mapper(mapper, model=model, **mapper_kw)
        self._lock = threading.Lock()

    def __len__(self):
        return len(self.loops)

    # ------------------------------------------------------------ routing
    def _states(self) -> List[InstanceState]:
        """Live load snapshot of every loop, for the mapper."""
        out = []
        for i, lp in enumerate(self.loops):
            eng = lp.eng
            with lp._lock:
                queued = len(lp._inbox)
            queued += len(lp._future) + len(lp._waiting)
            active = sum(not f for f in eng.slot_free)
            toks = sum(rt.input_len + len(rt.generated)
                       for rt in eng.slot_req if rt is not None)
            out.append(InstanceState(
                instance_id=i, queue_depth=queued, active=active,
                free_slots=len(eng.free_slots()),
                free_blocks=eng.pool.available if eng.paged else 0,
                active_tokens=toks))
        return out

    # --------------------------------------------------------- submission
    def submit(self, prompt_tokens, *, max_new_tokens: int,
               slo: Optional[SLO] = None, task_type: str = "chat",
               arrival_time: Optional[float] = None,
               request: Optional[Request] = None,
               on_token=None) -> TokenStream:
        """Route one arrival to an instance and enqueue it there
        (thread-safe; same signature as :meth:`ServeLoop.submit`)."""
        if request is None:
            request = Request(
                req_id=-1, task_type=task_type, input_len=len(prompt_tokens),
                slo=slo if slo is not None else SLO(),
                output_len=max_new_tokens,
                arrival_time=arrival_time if arrival_time is not None
                else 0.0)
        with self._lock:       # mapper state (round-robin cursor, homes)
            inst = self.mapper.map_one(request, self._states())
        return self.loops[inst].submit(
            prompt_tokens, max_new_tokens=max_new_tokens,
            arrival_time=arrival_time, request=request, on_token=on_token)

    def submit_trace(self, pairs) -> List[TokenStream]:
        """Plan a whole ``[(Request, prompt_tokens)]`` trace through the
        mapper and submit each instance's queue in plan order (see
        module docstring: a planning mapper's per-instance order becomes
        that engine's admission order).  Returns streams in the original
        trace order."""
        pairs = list(pairs)
        reqs = [r for r, _ in pairs]
        with self._lock:
            plan = self.mapper.plan(reqs, self._states())
        streams: Dict[int, TokenStream] = {}
        for inst, order in enumerate(plan):
            for i in order:
                r, toks = pairs[i]
                streams[i] = self.loops[inst].submit(
                    toks, max_new_tokens=r.planning_output_len(), request=r)
        return [streams[i] for i in range(len(pairs))]

    # ----------------------------------------------------------- serving
    def start(self, warm_lengths: Sequence[int] = ()):
        """Warm every member loop, then stamp one shared epoch — if each
        loop stamped its own at warm time, loop 0's clock would run for
        the whole of loop 1..N's compile warm-up and every early arrival
        would be charged seconds of phantom waiting."""
        fresh = [lp for lp in self.loops if lp._t0 is None]
        for lp in fresh:
            lp.start(warm_lengths)
        t0 = time.perf_counter()
        for lp in fresh:
            lp._t0 = t0
        return self

    def serve(self, poll: float = 0.0002) -> Dict[int, dict]:
        """Drive every loop to completion concurrently; returns the
        merged result dict (disjoint request-id ranges)."""
        self.start()
        errs: List[BaseException] = []

        def run(lp):
            try:
                lp.serve(poll)
            except BaseException as e:   # surface worker failures
                errs.append(e)

        threads = [threading.Thread(target=run, args=(lp,), daemon=True)
                   for lp in self.loops]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        if errs:
            raise errs[0]
        return self.results()

    # ------------------------------------------------------------ output
    def results(self) -> Dict[int, dict]:
        out: Dict[int, dict] = {}
        for lp in self.loops:
            out.update(lp.results())
        return out

    def streams(self) -> Dict[int, TokenStream]:
        out: Dict[int, TokenStream] = {}
        for lp in self.loops:
            out.update(lp.streams())
        return out

    @property
    def metrics(self) -> ServingMetrics:
        """Fleet-wide aggregated metrics (union of per-loop sinks)."""
        return ServingMetrics.aggregate([lp.metrics for lp in self.loops])
