"""Per-request token streams with wall-clock timestamps.

A :class:`TokenStream` is the serving loop's delivery channel for one
request: every generated token is pushed as a :class:`TokenEvent`
stamped with the wall clock at delivery, so TTFT / time-between-tokens
/ e2e are *measured* quantities — what a streaming client would see —
rather than modelled ones.  Consumers can attach a callback
(``on_token``), iterate the stream (a blocking iterator backed by a
queue, safe to drain from another thread), or read the accumulated
events after the fact.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, List, NamedTuple, Optional


class TokenEvent(NamedTuple):
    """One delivered token: id, wall-clock delivery time (seconds on the
    serving loop's clock), and its 0-based position in the output."""
    token: int
    t: float
    index: int


_SENTINEL = object()


class TokenStream:
    """Token delivery channel for one request.

    States: open -> closed (finished) | failed (rejected/errored).
    ``push``/``close``/``fail`` are called by the serving loop; all
    reader APIs are safe from other threads.
    """

    def __init__(self, req_id: int,
                 on_token: Optional[Callable[[TokenEvent], None]] = None):
        self.req_id = req_id
        self.submit_time: Optional[float] = None   # stamped at ingestion
        self._events: List[TokenEvent] = []
        self._on_token = on_token
        self._q: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._done = False
        self._error: Optional[str] = None
        self.close_time: Optional[float] = None

    # ------------------------------------------------------------ writer
    def push(self, token: int, t: float):
        with self._lock:
            if self._done:
                raise RuntimeError(f"stream {self.req_id} is closed")
            ev = TokenEvent(int(token), float(t), len(self._events))
            self._events.append(ev)
        self._q.put(ev)
        if self._on_token is not None:
            self._on_token(ev)

    def close(self, t: float):
        with self._lock:
            self._done = True
            self.close_time = float(t)
        self._q.put(_SENTINEL)

    def fail(self, reason: str, t: float):
        with self._lock:
            self._done = True
            self._error = reason
            self.close_time = float(t)
        self._q.put(_SENTINEL)

    # ------------------------------------------------------------ reader
    def __iter__(self):
        """Blocking iterator over events (cross-thread safe): yields
        every :class:`TokenEvent` until the stream closes."""
        replayed = 0
        while True:
            with self._lock:
                if replayed < len(self._events):
                    ev = self._events[replayed]
                    replayed += 1
                    yielded = True
                else:
                    yielded = False
                    if self._done:
                        return
            if yielded:
                yield ev
                continue
            item = self._q.get()
            if item is _SENTINEL:
                return
            # the queue may replay events already yielded from the
            # backlog above — skip those
            if item.index >= replayed:
                replayed = item.index + 1
                yield item

    @property
    def events(self) -> List[TokenEvent]:
        with self._lock:
            return list(self._events)

    @property
    def tokens(self) -> List[int]:
        return [ev.token for ev in self.events]

    @property
    def done(self) -> bool:
        return self._done

    @property
    def error(self) -> Optional[str]:
        return self._error

    # --------------------------------------------------- measured metrics
    def ttft(self) -> Optional[float]:
        """Wall-clock time to first token, from submission."""
        evs = self.events
        if not evs or self.submit_time is None:
            return None
        return evs[0].t - self.submit_time

    def tbts(self) -> List[float]:
        """Wall-clock gaps between consecutive token deliveries."""
        evs = self.events
        return [b.t - a.t for a, b in zip(evs, evs[1:])]

    def e2e(self) -> Optional[float]:
        """Submission -> last token delivery (wall clock)."""
        evs = self.events
        if not evs or self.submit_time is None:
            return None
        return evs[-1].t - self.submit_time
