"""Async streaming serving: a long-running loop over the slot-pool
engine with arrival-timed ingestion, per-request token streams, and
overlapped host-scheduling / device-execution.  Wall-clock TTFT / TBT /
e2e are *measured* at the token-delivery boundary rather than modelled.
"""
from repro.serving.loop import ServeLoop, UnsupportedDisciplineError
from repro.serving.metrics import (RequestTimeline, ServingMetrics,
                                   StepGauge)
from repro.serving.stream import TokenEvent, TokenStream

__all__ = ["ServeLoop", "UnsupportedDisciplineError", "ServingMetrics",
           "RequestTimeline", "StepGauge", "TokenEvent", "TokenStream"]
