"""Async streaming serving: a long-running loop over the slot-pool
engine with arrival-timed ingestion, per-request token streams, and
overlapped host-scheduling / device-execution.  Wall-clock TTFT / TBT /
e2e are *measured* at the token-delivery boundary rather than modelled.
:class:`EngineFleet` scales the same loop data-parallel: N engines
behind one submission queue, routed by an
:class:`~repro.core.policies.InstanceMapper`.
"""
from repro.serving.fleet import EngineFleet
from repro.serving.loop import ServeLoop, UnsupportedDisciplineError
from repro.serving.metrics import (RequestTimeline, ServingMetrics,
                                   StepGauge)
from repro.serving.stream import TokenEvent, TokenStream

__all__ = ["EngineFleet", "ServeLoop", "UnsupportedDisciplineError",
           "ServingMetrics", "RequestTimeline", "StepGauge", "TokenEvent",
           "TokenStream"]
