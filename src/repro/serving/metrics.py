"""Serving observability for the streaming loop: measured per-request
timelines, per-step gauges, and wall-clock SLO/goodput summaries.

Everything here is *measured* on the serving loop's wall clock — TTFT is
the delivery time of the first streamed token, TBT the gaps between
deliveries — as opposed to :mod:`repro.core.metrics`, which summarizes
modelled/engine-clock results after a batch run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.slo import Request, meets_slo


@dataclasses.dataclass
class RequestTimeline:
    """Measured wall-clock record of one served request."""
    req_id: int
    task_type: str
    arrival: float              # requested arrival (trace time)
    submit: float               # ingestion into the waiting queue
    first_token: Optional[float]   # wall clock of first delivery
    finish: Optional[float]        # wall clock of last delivery
    n_tokens: int
    tbt: List[float]            # gaps between consecutive deliveries
    preemptions: int = 0
    cached_tokens: int = 0
    rejected: bool = False

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token is None:
            return None
        return self.first_token - self.submit

    @property
    def e2e(self) -> Optional[float]:
        if self.finish is None:
            return None
        return self.finish - self.submit

    @property
    def tpot(self) -> Optional[float]:
        """(e2e - ttft) / n_tokens — the engine's accounting definition,
        so wall-clock attainment is judged on the same quantity."""
        if self.finish is None or self.first_token is None:
            return None
        return (self.finish - self.first_token) / max(self.n_tokens, 1)


@dataclasses.dataclass
class StepGauge:
    """Loop-state sample taken once per serving tick."""
    t: float
    queue_depth: int            # requests waiting for admission
    active: int                 # occupied slots
    free_blocks: int            # KV pool occupancy (-1: unpaged)
    dispatch_width: int         # pow-2 batch bucket of the tick (0: idle)
    overlapped: bool            # a step was in flight during this tick
    prefill_tokens: int = 0     # prompt tokens computed this tick (plan)
    prefilling: int = 0         # slots mid-prefill after this tick

    @property
    def mixed(self) -> bool:
        """The tick carried prefill work AND dispatched decodes — the
        chunk-as-tick batch composition."""
        return self.prefill_tokens > 0 and self.dispatch_width > 0


def _pct(xs, p):
    return float(np.percentile(np.asarray(xs), p)) if len(xs) else 0.0


class ServingMetrics:
    """Sink the :class:`~repro.serving.loop.ServeLoop` feeds.

    Collects per-request :class:`RequestTimeline`\\ s (from the token
    streams' delivery timestamps), per-step :class:`StepGauge` samples,
    and SLO-attainment bookkeeping; ``summary()`` reduces them to the
    numbers a load test reports."""

    def __init__(self):
        self.timelines: Dict[int, RequestTimeline] = {}
        self.gauges: List[StepGauge] = []
        self._met: Dict[int, bool] = {}

    # ------------------------------------------------------------- feeds
    def on_finish(self, req: Request, tl: RequestTimeline):
        self.timelines[tl.req_id] = tl
        if not tl.rejected and tl.e2e is not None:
            self._met[tl.req_id] = meets_slo(
                req, tl.e2e, tl.ttft if tl.ttft is not None else 0.0,
                tl.tpot if tl.tpot is not None else 0.0)
        else:
            self._met[tl.req_id] = False

    def on_gauge(self, g: StepGauge):
        self.gauges.append(g)

    @classmethod
    def aggregate(cls, parts: "List[ServingMetrics]") -> "ServingMetrics":
        """Fleet-wide view: merge per-loop sinks into one.  Request ids
        are disjoint across fleet members (``ServeLoop(id_base=...)``),
        so timelines/attainment merge by union; gauges interleave by
        tick time.  ``summary()`` on the result reports fleet
        attainment/goodput over every request and sums token
        throughput."""
        out = cls()
        for p in parts:
            out.timelines.update(p.timelines)
            out._met.update(p._met)
            out.gauges.extend(p.gauges)
        out.gauges.sort(key=lambda g: g.t)
        return out

    # ----------------------------------------------------------- reports
    def met(self, req_id: int) -> bool:
        return self._met.get(req_id, False)

    def summary(self) -> Dict[str, float]:
        done = [tl for tl in self.timelines.values() if not tl.rejected
                and tl.finish is not None]
        rejected = sum(tl.rejected for tl in self.timelines.values())
        ttfts = [tl.ttft for tl in done if tl.ttft is not None]
        tbts = [g for tl in done for g in tl.tbt]
        e2es = [tl.e2e for tl in done]
        n_tokens = sum(tl.n_tokens for tl in done)
        met = sum(self._met.get(tl.req_id, False) for tl in done)
        wall = max((tl.finish for tl in done), default=0.0)
        out = {
            "n": len(done),
            "rejected": rejected,
            "attainment": met / len(done) if done else 0.0,
            # Eq. 2 goodput on measured e2e: met count per unit latency
            "G": met / sum(e2es) if e2es and sum(e2es) > 0 else 0.0,
            "tokens": n_tokens,
            "tokens_per_s": n_tokens / wall if wall > 0 else 0.0,
            "ttft_mean": float(np.mean(ttfts)) if ttfts else 0.0,
            "ttft_p90": _pct(ttfts, 90),
            "tbt_mean": float(np.mean(tbts)) if tbts else 0.0,
            "tbt_p50": _pct(tbts, 50),
            "tbt_p90": _pct(tbts, 90),
            "e2e_mean": float(np.mean(e2es)) if e2es else 0.0,
            "preemptions": sum(tl.preemptions for tl in done),
        }
        if self.gauges:
            out["queue_depth_mean"] = float(
                np.mean([g.queue_depth for g in self.gauges]))
            out["queue_depth_max"] = max(g.queue_depth for g in self.gauges)
            out["occupancy_mean"] = float(
                np.mean([g.active for g in self.gauges]))
            out["overlap_frac"] = float(
                np.mean([g.overlapped for g in self.gauges]))
            out["prefill_tokens"] = sum(
                g.prefill_tokens for g in self.gauges)
            # fraction of ticks mixing prefill spans with decode
            # dispatch — 0.0 under StallingPrefill unless a prefill
            # shares its tick with an in-flight decode's delivery
            out["mixed_tick_frac"] = float(
                np.mean([g.mixed for g in self.gauges]))
        return out

    def rows(self, prefix: str = "serve"):
        """Benchmark-harness rows (``name, us_per_call, derived``)."""
        s = self.summary()
        derived = (f"att={s['attainment']:.3f};G={s['G']:.4f};"
                   f"n={s['n']};tok={s['tokens']};"
                   f"ttft_mean={s['ttft_mean']:.4f};"
                   f"tbt_mean={s['tbt_mean']:.5f};"
                   f"tbt_p90={s['tbt_p90']:.5f};"
                   f"tok_s={s['tokens_per_s']:.1f}")
        return [[f"{prefix}_summary", round(s["e2e_mean"] * 1e6, 1),
                 derived]]
