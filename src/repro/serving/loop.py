"""Async streaming serving loop: overlapped host scheduling + device
execution over the slot-pool engine.

Everything before this module was batch-oriented — ``Engine.run_policy``
consumes a fixed workload list and syncs the device every step, so
host-side scheduling (annealing, admission, block accounting) serializes
with execution and latency is charged on the engine's step-time clock.
:class:`ServeLoop` turns the same engine into a long-running service:

* **Submission + arrival-timed ingestion** — ``submit()`` enqueues a
  request (thread-safe) with an optional future ``arrival_time``; the
  loop releases it into the waiting queue once that instant passes on
  the wall clock, so Poisson traces replay in real time.
* **Token streaming** — every generated token is delivered through the
  request's :class:`~repro.serving.stream.TokenStream` with a wall-clock
  timestamp: TTFT / TBT / e2e are *measured at the delivery boundary*,
  exactly what a streaming client observes.
* **Overlapped execution** (``overlap=True``) — decode round ``N+1`` is
  dispatched from device-resident token state *before* round ``N``'s
  sampled ids are read back (the engine's fused decode+sample keeps them
  on device).  While the device computes, the host delivers round
  ``N-1``'s tokens, runs the scheduling policy, updates block accounting
  and the prefix index.  One decode round of lookahead means host state
  lags the device by at most one round; a request that finishes mid-
  lookahead has its overshoot token dropped at readback (identity-
  guarded delivery), and requests whose output budget is provably
  exhausted are excluded from the next dispatch up front, so greedy
  decoding is token-for-token identical to the synchronous mode.
* **Pow-2 batch buckets** (``bucket_batches=True``, paged engines) —
  each round is dispatched over the smallest power-of-two slot prefix
  covering every active slot, so arrival jitter changes the compiled
  shape only at bucket boundaries (at most ``log2(max_slots)``
  compilations, pre-warmed in ``start()``).
* **Chunk-as-tick prefill** — every tick executes the active
  :class:`~repro.core.policies.ExecutionDiscipline`'s ``StepPlan``:
  staged admissions (``Engine.begin_prefill``) advance chunk-by-chunk
  under ``ChunkedPrefill(n)`` / ``dynamic-chunk`` while the running
  decode round dispatches in the same tick, so a long prompt no longer
  stalls streaming TBT for its whole prefill.  ``StallingPrefill``
  (default) completes each prefill within its admission tick.

The scheduling brain is unchanged: the same v2
:class:`~repro.core.policies.SchedulingPolicy` objects drive admission
and preemption through :meth:`Engine.build_view`, with SLO budgets
shifted by true wall-clock waiting.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.latency_model import LinearLatencyModel
from repro.core.policies import (ChunkedPrefill, make_discipline,
                                 normalize_decision, resolve_policy)
from repro.core.slo import SLO, Request
from repro.engine.engine import Engine, _bucket
from repro.engine.request import Phase, RuntimeRequest
from repro.serving.metrics import (RequestTimeline, ServingMetrics,
                                   StepGauge)
from repro.serving.stream import TokenStream


class UnsupportedDisciplineError(NotImplementedError):
    """The requested discipline cannot run on this engine.  Since the
    step-planner refactor the streaming loop executes chunked and
    adaptive disciplines natively (prefill chunks ride the tick plan
    alongside decode dispatches), so this is raised only for the one
    genuinely unsupported combination: chunked prefill on an MLA arch,
    which has no chunked forward path.  Subclassing
    ``NotImplementedError`` keeps pre-existing callers' handlers
    working."""


class _Ticket:
    """One in-flight decode round: the device array of sampled ids plus
    the (slot, request, expected-index) participants recorded at
    dispatch time.  Identity-guarded consumption: a participant whose
    request finished, was preempted, or whose slot was reassigned while
    the round was in flight has its token dropped."""

    __slots__ = ("tokens", "parts", "width", "t_dispatch")

    def __init__(self, tokens, parts, width, t_dispatch):
        self.tokens = tokens
        self.parts: List[Tuple[int, RuntimeRequest, int]] = parts
        self.width = width
        self.t_dispatch = t_dispatch


class ServeLoop:
    """Long-running streaming serving loop over an :class:`Engine`.

    Parameters
    ----------
    engine:
        A fresh engine (its slot pool and KV pool become the service's).
    policy:
        v2 policy object or any ``repro.core.policies.make`` registry
        key (``"fcfs"``, ``"slo-reanneal[:jax]"``, ``"slo-preempt"``…).
    model:
        Latency model for slack/budget projections (policies that carry
        their own are used as fallback).
    discipline:
        :class:`~repro.core.policies.ExecutionDiscipline` or registry
        key (``"stall"``, ``"chunked:32"``).  Default resolution
        matches ``Engine.run_policy``: the policy's own discipline
        (``dynamic-chunk``), else the engine's ``chunked_prefill``
        setting, else stalling whole-prompt prefill.  Chunked on an MLA
        arch raises :class:`UnsupportedDisciplineError`.
    overlap:
        Dispatch round ``N+1`` before syncing round ``N`` (one-step
        lookahead).  ``False`` = synchronous reference mode: identical
        code path, but every round is read back immediately.
    bucket_batches:
        Pad decode dispatches to pow-2 slot-prefix buckets (paged
        engines only) instead of always running the full slot pool.
    id_base:
        Starting request id — fleet members get disjoint ranges so one
        aggregated metrics/result namespace never collides.
    """

    def __init__(self, engine: Engine, policy="fcfs", *,
                 model: Optional[LinearLatencyModel] = None,
                 discipline=None, overlap: bool = True,
                 bucket_batches: bool = True,
                 metrics: Optional[ServingMetrics] = None,
                 id_base: int = 0):
        self.eng = engine
        self.pol, self.preemptive = resolve_policy(
            policy, model=model, max_batch=engine.max_slots)
        self.model = model if model is not None \
            else getattr(self.pol, "model", None)
        if discipline is None:
            # same resolution as Engine.run_policy: a policy carrying
            # its own discipline (dynamic-chunk) wins, then the
            # engine's chunked_prefill default — object identity is
            # preserved so adaptive retuning reaches the tick planner
            discipline = getattr(self.pol, "discipline", None)
        if discipline is None and engine.chunked_prefill:
            discipline = ChunkedPrefill(engine.chunked_prefill)
        self.disc = make_discipline(discipline)
        if self.disc.chunk_size and engine.cfg.mla is not None:
            raise UnsupportedDisciplineError(
                f"{self.disc!r} is unsupported for MLA archs (no "
                "chunked forward path); use whole-prompt (stalling) "
                "prefill")
        self.overlap = overlap
        self.bucket_batches = bucket_batches and engine.paged
        self.metrics = metrics if metrics is not None else ServingMetrics()

        self._lock = threading.Lock()
        self._inbox: deque = deque()         # submitted, not yet ingested
        self._future: List[RuntimeRequest] = []   # ingested, arrival ahead
        self._waiting: List[RuntimeRequest] = []
        self._streams: Dict[int, TokenStream] = {}
        self._requests: Dict[int, RuntimeRequest] = {}
        self._inflight: Optional[_Ticket] = None
        self._feed = None                    # [max_slots, 1] device ids
        self._t0: Optional[float] = None
        # id_base offsets request ids so loops sharing a fleet-wide
        # metrics/result namespace never collide
        self._next_id = id_base
        self._stall_spins = 0
        self._stopped = False

    # ------------------------------------------------------------- clock
    def now(self) -> float:
        """Wall-clock seconds since ``start()``."""
        if self._t0 is None:
            raise RuntimeError("loop not started")
        return time.perf_counter() - self._t0

    # ------------------------------------------------------------ warmup
    def start(self, warm_lengths: Sequence[int] = ()):
        """Compile-warm the dispatch buckets (and optionally the prefill
        length buckets a trace will hit) and stamp the epoch.  Warmup
        runs *before* the clock starts so first-seen compiles never
        pollute measured TTFT/TBT."""
        if self._t0 is not None:
            return self
        eng = self.eng
        if self._feed is None:
            self._feed = jnp.zeros((eng.max_slots, 1), jnp.int32)
        widths = {eng.max_slots}
        if self.bucket_batches:
            w = 1
            while w <= eng.max_slots:
                widths.add(min(w, eng.max_slots))
                w *= 2
        idle = np.zeros(eng.max_slots, bool)
        for w in sorted(widths):
            eng.dispatch_decode(self._feed, idle, width=w)
        exact = bool(eng.cfg.ssm_layers)     # SSM archs prefill unpadded
        for n in sorted({int(n) if exact else _bucket(int(n))
                         for n in warm_lengths}):
            if ("prefill", n) in eng._warm or n >= eng.max_seq_len:
                continue
            toks = jnp.zeros((1, n), jnp.int32)
            if eng.paged:
                eng._warm_paged(eng._prefill_fn, toks, n, 0)
            else:
                eng._prefill_fn(eng.params, toks, n)[0].block_until_ready()
            eng._warm.add(("prefill", n))
        # chunked disciplines: pre-warm the chunk buckets a plan can hit
        # (every pow-2 bucket up to the largest chunk, so ragged final
        # chunks are covered too).  Adaptive policies may retune up to
        # their max_chunk.
        C = self.disc.chunk_size
        if C and eng.paged:
            hi = _bucket(max(C, getattr(self.pol, "max_chunk", C)))
            L = 16
            while L <= hi and L < eng.max_seq_len:
                if ("chunk", L) not in eng._warm:
                    toks = jnp.zeros((1, L), jnp.int32)
                    eng._warm_paged(eng._chunk_fn, toks, 0, L)
                    eng._warm.add(("chunk", L))
                L *= 2
        self._t0 = time.perf_counter()
        return self

    # -------------------------------------------------------- submission
    def submit(self, prompt_tokens, *, max_new_tokens: int,
               slo: Optional[SLO] = None, task_type: str = "chat",
               arrival_time: Optional[float] = None,
               request: Optional[Request] = None,
               on_token=None) -> TokenStream:
        """Enqueue one request (thread-safe) and return its token stream.

        ``arrival_time`` (loop-relative seconds) schedules a future
        arrival — trace replay submits the whole workload up front and
        the loop releases each request when its instant passes on the
        wall clock.  ``None`` = arrive immediately.  ``request`` passes
        a pre-built :class:`Request` (its ``arrival_time`` is used when
        the kwarg is None)."""
        prompt = np.asarray(prompt_tokens, np.int32)
        with self._lock:
            rid = self._next_id
            self._next_id += 1
        if request is None:
            request = Request(
                req_id=rid, task_type=task_type, input_len=len(prompt),
                slo=slo if slo is not None else SLO(),
                output_len=max_new_tokens,
                arrival_time=arrival_time if arrival_time is not None
                else 0.0)
        else:
            request.req_id = rid
            if arrival_time is not None:
                request.arrival_time = arrival_time
        rt = RuntimeRequest(request=request, prompt_tokens=prompt,
                            max_new_tokens=int(max_new_tokens))
        stream = TokenStream(rid, on_token=on_token)
        with self._lock:
            self._streams[rid] = stream
            self._requests[rid] = rt
            self._inbox.append(rt)
        return stream

    def submit_trace(self, pairs) -> List[TokenStream]:
        """Submit a ``[(Request, prompt_tokens)]`` trace (the
        ``data.synthetic`` token-workload format) for wall-clock
        replay."""
        return [self.submit(toks, max_new_tokens=r.planning_output_len(),
                            request=r) for r, toks in pairs]

    # --------------------------------------------------------- ingestion
    def _reject(self, rt: RuntimeRequest, reason: str, t: float):
        stream = self._streams[rt.req_id]
        stream.submit_time = t
        stream.fail(reason, t)
        self.metrics.on_finish(rt.request, RequestTimeline(
            req_id=rt.req_id, task_type=rt.request.task_type,
            arrival=rt.request.arrival_time, submit=t, first_token=None,
            finish=None, n_tokens=0, tbt=[], rejected=True))

    def _ingest(self, t: float):
        """Move submissions into the arrival schedule, and release every
        request whose arrival instant has passed into the waiting
        queue — stamping ``submit_time`` on the loop clock so policy
        budget shifting sees true wall waiting."""
        with self._lock:
            newly = list(self._inbox)
            self._inbox.clear()
        for rt in newly:
            eng = self.eng
            if rt.input_len >= eng.max_seq_len:
                self._reject(rt, f"prompt length {rt.input_len} >= "
                                 f"max_seq_len {eng.max_seq_len}", t)
            elif eng.paged and eng._blocks_needed(rt) > eng.pool.total:
                self._reject(rt, f"needs {eng._blocks_needed(rt)} KV "
                                 f"blocks, pool holds {eng.pool.total}", t)
            else:
                self._future.append(rt)
        if newly:
            self._future.sort(key=lambda rt: rt.request.arrival_time)
        while self._future and self._future[0].request.arrival_time <= t:
            rt = self._future.pop(0)
            # queueing from the true arrival instant counts toward
            # budgets — a request that arrived mid-step waited too
            rt.submit_time = min(rt.request.arrival_time, t)
            rt.request.submit_time = rt.submit_time
            self._streams[rt.req_id].submit_time = rt.submit_time
            self._waiting.append(rt)

    # -------------------------------------------------------- scheduling
    def _retune(self):
        """Let an adaptive policy resize its chunk against the current
        active set on ticks where ``decide()`` doesn't run (empty
        queue) — same hook as the batch loop and the event core."""
        fn = getattr(self.pol, "retune", None)
        if fn is not None and not all(self.eng.slot_free):
            fn(self.eng.build_view([], self.disc, self.model))

    def _schedule(self):
        """One policy decision over the live view: preempt, then reserve
        blocks and *stage* admissions (``begin_prefill``).  The staged
        prefills advance through the tick plan in :meth:`tick` — whole-
        prompt in one tick under stall, chunk-by-chunk alongside decode
        dispatches under a chunked discipline."""
        eng = self.eng
        if not self._waiting:
            self._retune()
            return False
        free = eng.free_slots()
        if not free and not (self.preemptive and not all(eng.slot_free)):
            self._retune()
            return False
        view = eng.build_view(self._waiting, self.disc, self.model)
        admit, preempt = normalize_decision(self.pol.decide(view), view)
        active_rts = eng.active_requests()
        did = False
        for j in preempt:
            vict = active_rts[j]
            # re-prefill must fit: prompt + generated + next token
            if vict.input_len + len(vict.generated) + 1 >= eng.max_seq_len:
                continue
            eng.preempt(vict)
            self._waiting.append(vict)       # view indices stay valid
            did = True
        free = eng.free_slots()
        sel = []
        for j in admit:
            if len(sel) >= len(free):
                break
            # reserve atomically (alias cached prefix + alloc the rest)
            # so same-tick admissions never race a probe against a later
            # allocation
            if eng.paged and not eng._reserve_blocks(self._waiting[j]):
                continue                     # out of KV blocks: wait
            sel.append(j)
        chosen = [self._waiting[j] for j in sel]
        for j in sorted(sel, reverse=True):
            self._waiting.pop(j)
        for rt, slot in zip(chosen, free):
            # stage only: the prefill runs via this tick's plan below
            eng.begin_prefill(rt, slot)
            did = True
        return did

    def _after_prefill(self, rt: RuntimeRequest):
        """Deliver the token(s) a synchronous prefill produced (one, or
        the catch-up after a preemption re-prefill) and seed the device
        feed for the next decode round."""
        t = self.now()
        stream = self._streams[rt.req_id]
        for idx in range(len(stream.events), len(rt.generated)):
            stream.push(rt.generated[idx], t)
        if rt.phase is Phase.FINISHED:       # finished at prefill
            self._finish(rt, t, slot_done=True)
        else:
            self._feed = self._feed.at[rt.slot, 0].set(rt.generated[-1])

    # ---------------------------------------------------------- dispatch
    def _inflight_count(self, rt: RuntimeRequest) -> int:
        """Decode rounds in flight for ``rt`` (0 or 1): its host token
        count lags the device by this many tokens."""
        if self._inflight is None:
            return 0
        return sum(1 for s, r, i in self._inflight.parts
                   if r is rt and i == len(rt.generated))

    def _dispatch_round(self) -> Optional[_Ticket]:
        """Dispatch one fused decode+sample round over the active slots
        (minus requests whose output budget is provably exhausted after
        the in-flight round) without waiting for it."""
        eng = self.eng
        parts: List[Tuple[int, RuntimeRequest, int]] = []
        active = np.zeros(eng.max_slots, bool)
        for slot, rt in enumerate(eng.slot_req):
            if rt is None or rt.phase is not Phase.RUNNING:
                continue
            ahead = self._inflight_count(rt)
            if len(rt.generated) + ahead >= rt.max_new_tokens:
                continue                     # will finish at readback
            active[slot] = True
            parts.append((slot, rt, len(rt.generated) + ahead))
        if not parts:
            return None
        width = eng.max_slots
        if self.bucket_batches:
            width = min(_bucket(max(s for s, _, _ in parts) + 1, lo=1),
                        eng.max_slots)
        toks = eng.dispatch_decode(
            self._feed, active, width=width,
            lookahead=1 if self._inflight is not None else 0)
        self._feed = self._feed.at[:width, 0].set(toks)
        return _Ticket(toks, parts, width, self.now())

    # ----------------------------------------------------------- consume
    def _consume(self, ticket: _Ticket):
        """Read back one round's sampled ids (syncing the device up to
        that round) and deliver them with wall timestamps."""
        toks = np.asarray(ticket.tokens)
        t = self.now()
        for slot, rt, idx in ticket.parts:
            # identity guard: deliver only if the request is still the
            # running occupant of this slot and no token landed since
            # dispatch (preempted/finished/reassigned -> drop overshoot)
            if (rt.phase is not Phase.RUNNING or rt.slot != slot
                    or len(rt.generated) != idx):
                continue
            self._deliver(rt, int(toks[slot]), t)

    def _deliver(self, rt: RuntimeRequest, tok: int, t: float):
        eng = self.eng
        rt.generated.append(tok)
        self._streams[rt.req_id].push(tok, t)
        if (eng.eos >= 0 and tok == eng.eos) or \
                len(rt.generated) >= rt.max_new_tokens:
            rt.phase = Phase.FINISHED
            rt.finish_time = t
            eng.finish_slot(rt)
            self._finish(rt, t, slot_done=False)

    def _finish(self, rt: RuntimeRequest, t: float, slot_done: bool):
        stream = self._streams[rt.req_id]
        stream.close(t)
        evs = stream.events
        self.metrics.on_finish(rt.request, RequestTimeline(
            req_id=rt.req_id, task_type=rt.request.task_type,
            arrival=rt.request.arrival_time, submit=rt.submit_time,
            first_token=evs[0].t if evs else None,
            finish=evs[-1].t if evs else None,
            n_tokens=len(evs), tbt=stream.tbts(),
            preemptions=rt.preemptions, cached_tokens=rt.cached_tokens))

    # -------------------------------------------------------------- tick
    def _idle(self) -> bool:
        return (self._inflight is None and all(self.eng.slot_free)
                and not self._waiting)

    def _done(self) -> bool:
        with self._lock:
            inbox = len(self._inbox)
        return inbox == 0 and not self._future and self._idle()

    def _run_prefill_plan(self) -> int:
        """Advance every staged prefill by its planned span (the
        streaming half of ``Engine.execute_step`` — decode runs through
        the overlapped dispatch path instead).  The prefill jits chain
        after any in-flight decode round, so device order stays valid;
        a completing span delivers the first token and seeds the
        dispatch feed.  Returns prompt tokens computed this tick."""
        eng = self.eng
        plan = eng.plan_step(self.disc)
        done = 0
        for it in plan.prefills:
            rt = eng.slot_req[it.ref]
            if rt is None or rt.phase is not Phase.PREFILLING:
                continue
            eng.prefill_step(rt, it.length)
            done += it.length
            if rt.phase is not Phase.PREFILLING:     # completed
                self._after_prefill(rt)
        return done

    def tick(self):
        """One serving iteration: ingest -> schedule (stage admissions)
        -> prefill plan spans -> dispatch round N -> deliver round N-1
        (overlap) or round N (sync) -> gauges.  Under a chunked
        discipline a long prompt's chunk and the running decode round
        share every tick (chunk-as-tick)."""
        t = self.now()
        self._ingest(t)
        self.eng.clock = t          # engine stamps land on the wall clock
        admitted = self._schedule()
        pre_tok = self._run_prefill_plan()
        ticket = self._dispatch_round()
        prev, self._inflight = self._inflight, ticket
        if prev is not None:
            self._consume(prev)
        if not self.overlap and ticket is not None:
            self._consume(ticket)
            self._inflight = None
        self.metrics.on_gauge(StepGauge(
            t=t, queue_depth=len(self._waiting),
            active=sum(not f for f in self.eng.slot_free),
            free_blocks=self.eng.pool.available if self.eng.paged else -1,
            dispatch_width=ticket.width if ticket else 0,
            overlapped=prev is not None and ticket is not None,
            prefill_tokens=pre_tok,
            prefilling=sum(1 for rt in self.eng.slot_req
                           if rt is not None
                           and rt.phase is Phase.PREFILLING)))
        # stall detection: completely idle with a non-empty queue and a
        # policy that admits nothing (matches the batch loop's guard)
        if (ticket is None and self._inflight is None and self._waiting
                and not admitted and all(self.eng.slot_free)):
            self._stall_spins += 1
            if self._stall_spins > 4:
                rt = self._waiting[0]
                if self.eng.paged and all(
                        self.eng._unique_blocks_needed(w)
                        > self.eng._admission_blocks()
                        for w in self._waiting):
                    raise ValueError(
                        f"request {rt.req_id} needs "
                        f"{self.eng._unique_blocks_needed(rt)} KV blocks "
                        f"but only {self.eng._admission_blocks()} exist")
                raise RuntimeError(
                    "admission stalled: policy admitted nothing while "
                    "the loop was idle")
        else:
            self._stall_spins = 0

    def serve(self, poll: float = 0.0002):
        """Run until every submitted request has completed (and no
        future arrivals remain).  Between idle ticks the loop sleeps to
        the next scheduled arrival."""
        self.start()
        while not self._done():
            self.tick()
            if self._idle():
                with self._lock:
                    empty_inbox = not self._inbox
                if self._future and empty_inbox:
                    gap = self._future[0].request.arrival_time - self.now()
                    if gap > 0:
                        time.sleep(min(gap, 0.05))
                elif empty_inbox and not self._future:
                    continue            # _done() will see it
                else:
                    time.sleep(poll)
        return self.results()

    def drain(self):
        """Consume any in-flight round (used when driving ``tick()``
        manually)."""
        if self._inflight is not None:
            self._consume(self._inflight)
            self._inflight = None

    # ------------------------------------------------------------ output
    def results(self) -> Dict[int, dict]:
        """Engine-style result dict over every completed request."""
        done = [rt for rt in self._requests.values()
                if rt.phase is Phase.FINISHED]
        out = self.eng._collect(done)
        for rid in out:
            out[rid]["met_wall"] = self.metrics.met(rid)
        return out

    def streams(self) -> Dict[int, TokenStream]:
        return dict(self._streams)
