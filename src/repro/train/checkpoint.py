"""Checkpointing: save/restore params + optimizer state pytrees.

Format: one ``.npz`` with flattened key paths plus a small JSON manifest —
dependency-free, deterministic, and safe to memory-map on restore. Sharded
arrays are gathered by ``np.asarray`` (host-local in this container; on a
real pod use one process per host with ``jax.experimental.multihost_utils``).
"""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np

from repro.train.optimizer import AdamWState

_SEP = "//"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, params, opt_state: AdamWState = None, step: int = 0,
         meta: dict = None):
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    payload = {"params" + _SEP + k: v for k, v in _flatten(params).items()}
    if opt_state is not None:
        payload.update({"mu" + _SEP + k: v
                        for k, v in _flatten(opt_state.mu).items()})
        payload.update({"nu" + _SEP + k: v
                        for k, v in _flatten(opt_state.nu).items()})
        payload["opt_step"] = np.asarray(opt_state.step)
    np.savez(path, **payload)
    with open(path + ".json", "w") as f:
        json.dump({"step": step, "meta": meta or {},
                   "has_opt": opt_state is not None}, f)


def _unflatten_into(template, flat: dict, prefix: str):
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for path, leaf in leaves_with_path:
        key = prefix + _SEP + _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def restore(path: str, params_template, opt_template: AdamWState = None
            ) -> Tuple[Any, Any, int]:
    """Returns (params, opt_state_or_None, step)."""
    with np.load(path if path.endswith(".npz") else path + ".npz") as z:
        flat = {k: z[k] for k in z.files}
    with open((path if not path.endswith(".npz") else path[:-4]) + ".json"
              if not os.path.exists(path + ".json") else path + ".json") as f:
        manifest = json.load(f)
    params = _unflatten_into(params_template, flat, "params")
    opt_state = None
    if opt_template is not None and manifest.get("has_opt"):
        mu = _unflatten_into(opt_template.mu, flat, "mu")
        nu = _unflatten_into(opt_template.nu, flat, "nu")
        opt_state = AdamWState(step=jax.numpy.asarray(flat["opt_step"]),
                               mu=mu, nu=nu)
    return params, opt_state, manifest["step"]
