"""Training step: causal-LM loss + AdamW update, pjit-shardable."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import forward_full
from repro.models.moe import ShardingCtx
from repro.train import optimizer as opt

AUX_LOSS_WEIGHT = 0.01


def lm_loss(params, cfg: ModelConfig, tokens, labels, mask=None,
            embeds=None, ctx: Optional[ShardingCtx] = None,
            remat: bool = True):
    """Mean next-token cross entropy (+ MoE aux). labels: [B,S] (or
    [B,S,K] for multi-codebook audio), -100 = ignore."""
    logits, _, aux = forward_full(params, cfg, tokens=tokens, embeds=embeds,
                                  ctx=ctx, remat=remat)
    valid = (labels >= 0)
    safe = jnp.maximum(labels, 0)
    # Sharding-friendly cross entropy: select the target logit with a
    # masked sum over the (vocab-sharded) class dim instead of
    # take_along_axis — GSPMD then emits tiny [B,S] all-reduces rather
    # than gathering/permuting the full [B,S,V] logits (§Perf iteration 2).
    lg = logits.astype(jnp.float32)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
    target = jnp.sum(jnp.where(vocab_iota == safe[..., None], lg, 0.0),
                     axis=-1)
    m = jnp.max(lg, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(lg - m[..., None]), axis=-1))
    nll = lse - target
    if mask is not None:
        valid = valid & (mask > 0)
    denom = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(jnp.where(valid, nll, 0.0)) / denom
    return loss + AUX_LOSS_WEIGHT * aux, {"lm_loss": loss, "aux_loss": aux}


def train_step(cfg: ModelConfig, opt_cfg: opt.AdamWConfig, params, opt_state,
               batch, ctx: Optional[ShardingCtx] = None, remat: bool = True):
    """batch: {"tokens": [B,S], "labels": [B,S]} (or "embeds" for VLM).

    Pure function — safe to jit/pjit with in/out shardings.
    """
    def loss_fn(p):
        return lm_loss(p, cfg, batch.get("tokens"), batch["labels"],
                       mask=batch.get("mask"), embeds=batch.get("embeds"),
                       ctx=ctx, remat=remat)

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    params, opt_state, om = opt.apply(opt_cfg, params, grads, opt_state)
    metrics = dict(metrics, loss=loss, **om)
    return params, opt_state, metrics


def make_train_step(cfg, opt_cfg, ctx=None, remat=True):
    return partial(train_step, cfg, opt_cfg, ctx=ctx, remat=remat)
