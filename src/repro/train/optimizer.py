"""AdamW in pure JAX (pytree state)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree):
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree))
    return jnp.sqrt(sum(leaves))


def apply(cfg: AdamWConfig, params, grads, state: AdamWState):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = _schedule(cfg, step.astype(jnp.float32))

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:        # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step, mu, nu), {"grad_norm": gnorm,
                                                  "lr": lr}
