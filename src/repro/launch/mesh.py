"""Mesh construction for production pods and local hosts.

Functions (NOT module-level constants) so importing this module never
touches jax device state.  Target hardware: TPU v5e pods — 256 chips/pod,
(16, 16) per pod, 2 pods = 512 chips for the multi-pod mesh.

``make_host_mesh`` builds a mesh over whatever the local host exposes —
including the virtual CPU devices created by
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — which is how
the sharding test-suite and ``benchmarks/bench_sharding.py`` exercise
real 8-way SPMD partitioning on a CPU-only container.
"""
from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(tp: Optional[int] = None, data: int = 1):
    """A ``(data, tp)`` mesh over the local devices (tests/examples).

    ``tp`` defaults to every local device not claimed by ``data`` —
    so under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    this is a (1, 8) tensor-parallel mesh, and on an ordinary
    single-device host it degrades to the old (1, 1) mesh.  Raises if
    the host cannot cover ``data * tp`` devices (jax.make_mesh checks).
    """
    n = jax.local_device_count()
    if tp is None:
        tp = max(1, n // max(data, 1))
    return jax.make_mesh((data, tp), ("data", "model"))
