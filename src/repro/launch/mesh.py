"""Production mesh construction.

A function (NOT a module-level constant) so importing this module never
touches jax device state.  Target hardware: TPU v5e pods — 256 chips/pod,
(16, 16) per pod, 2 pods = 512 chips for the multi-pod mesh.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """A 1x1 mesh over the single local device (tests/examples)."""
    return jax.make_mesh((1, 1), ("data", "model"))
