"""Serving launcher: live streaming loop (default) or batch engine run
on a synthetic mixed workload.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --n 12 --policy slo-reanneal:jax --arrival-rate 20

``--policy`` accepts ANY ``repro.core.policies.make`` registry name
(``fcfs``, ``priority``, ``slo-reanneal``, ``slo-reanneal:jax``,
``slo-preempt``, …) plus ``slo``/``planned`` for the offline Algorithm
1/2 planner (plan batches, then dispatch).  Streaming mode drives the
:class:`repro.serving.ServeLoop` — arrival-timed ingestion, per-token
wall-clock streams, overlapped host scheduling + device execution — and
reports *measured* TTFT/TBT/attainment; ``--mode batch`` runs the
engine's batch admission loop on its internal clock instead (the
planner policies always use batch mode: their plan needs the whole
workload up front).  ``--discipline chunked:<n>`` and
``--policy dynamic-chunk`` stream natively: prefill chunks ride the
serving ticks alongside running decode dispatches (chunk-as-tick).
``--instances N`` scales streaming mode data-parallel: an
:class:`repro.serving.EngineFleet` of N engines routed by ``--mapper``
(least-loaded default; ``annealed`` runs the paper's Algorithm 2 as the
routing plan — see docs/sharding.md).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.core import SAParams, SLOAwareScheduler
from repro.core.policies import make, make_discipline
from repro.core.profiler import LatencyProfiler
from repro.data.synthetic import sample_serve_workload
from repro.engine.engine import Engine
from repro.engine.request import RuntimeRequest
from repro.models import init_params
from repro.serving import EngineFleet, ServeLoop


def _to_rts(pairs):
    return [RuntimeRequest(request=r, prompt_tokens=p,
                           max_new_tokens=r.output_len)
            for r, p in pairs]


def fit_latency_model(cfg, params, max_batch, rng, n_warm=6):
    """Fit the linear latency model on a short profiled warmup run."""
    prof = LatencyProfiler()
    warm = Engine(cfg, params, max_slots=max_batch, max_seq_len=256,
                  profiler=prof)
    warm.run_fcfs(_to_rts(sample_serve_workload(n_warm, cfg.vocab_size,
                                                rng=rng)))
    return prof.fit()


def run_planner(eng, rts, model, discipline, max_batch, respect):
    """Offline Algorithm 1/2: plan batches, score, dispatch."""
    reqs = [rt.request for rt in rts]
    for rt, r in zip(rts, reqs):
        r.predicted_output_len = rt.max_new_tokens
    sched = SLOAwareScheduler(model, num_instances=1, max_batch=max_batch,
                              sa_params=SAParams(seed=0))
    outcome = sched.schedule(reqs)
    for disc in ("stall", f"chunked:{discipline.chunk_size or 32}"):
        ev = sched.evaluate_plan(outcome, discipline=disc)
        print(f"plan under {disc:<12}: predicted G={ev.G:.4f} "
              f"attainment={ev.attainment:.2f}")
    by_id = {rt.req_id: rt for rt in rts}
    planned = [[by_id[r.req_id] for r in b]
               for b in outcome.queues[0].batches]
    return eng.run_planned(planned, discipline=discipline, model=model,
                           respect_arrivals=respect)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--n", type=int, default=12)
    ap.add_argument("--policy", default="slo",
                    help="any policies.make registry name (fcfs, priority, "
                         "slo-reanneal[:jax], slo-preempt, ...) or "
                         "slo/planned for the offline planner")
    ap.add_argument("--mode", choices=("stream", "batch"), default="stream",
                    help="stream: live ServeLoop with measured wall-clock "
                         "metrics; batch: engine admission loop")
    ap.add_argument("--discipline", default="stall",
                    help="stall | chunked | chunked:<size> — both modes; "
                         "streaming runs chunks in the tick plan "
                         "alongside decode dispatches")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="requests/s; 0 = all submitted at t=0")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--no-overlap", action="store_true",
                    help="stream mode: synchronous reference loop")
    ap.add_argument("--instances", type=int, default=1,
                    help="stream mode: data-parallel EngineFleet size "
                         "(N engines behind one front door)")
    ap.add_argument("--mapper", default="least-loaded",
                    help="fleet routing: round-robin | least-loaded | "
                         "slo-affinity | memory-greedy | annealed")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.uses_extra_embeds:
        raise SystemExit("VLM serving needs an embedding frontend; use the "
                         "dry-run for qwen2-vl shapes")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    pairs = sample_serve_workload(args.n, cfg.vocab_size, rng=rng,
                                  arrival_rate=args.arrival_rate)
    discipline = make_discipline(args.discipline)
    model = fit_latency_model(cfg, params, args.max_batch, rng)

    eng = Engine(cfg, params, max_slots=args.max_batch, max_seq_len=256)
    planner = args.policy in ("slo", "planned")
    mode = args.mode
    if mode == "stream" and not planner:
        pol = make(args.policy, model=model, max_batch=args.max_batch)
        # a policy that carries its own discipline (dynamic-chunk) wins
        # over the flag — same convention as the batch path below.
        # Chunked disciplines stream natively (chunk-as-tick); only
        # MLA + chunked raises UnsupportedDisciplineError, which is a
        # real configuration error the user must fix.
        disc = getattr(pol, "discipline", None) or discipline
        if args.instances > 1:
            engines = [eng] + [Engine(cfg, params,
                                      max_slots=args.max_batch,
                                      max_seq_len=256)
                               for _ in range(args.instances - 1)]
            loop = EngineFleet(engines, args.policy, mapper=args.mapper,
                               model=model, discipline=disc,
                               overlap=not args.no_overlap)
        else:
            loop = ServeLoop(eng, pol, model=model, discipline=disc,
                             overlap=not args.no_overlap)
        loop.start(warm_lengths=[len(p) for _, p in pairs])
        loop.submit_trace(pairs)
        out = loop.serve()
        s = loop.metrics.summary()
        where = f"fleet{args.instances}:{args.mapper}" \
            if args.instances > 1 else "stream"
        print(f"policy={args.policy} mode={where} arch={cfg.name} "
              f"discipline={disc!r} overlap={not args.no_overlap} "
              f"G={s['G']:.4f} attainment={s['attainment']:.2f} "
              f"ttft_mean={s['ttft_mean'] * 1e3:.1f}ms "
              f"tbt_p90={s['tbt_p90'] * 1e3:.2f}ms "
              f"tok/s={s['tokens_per_s']:.0f} "
              f"preemptions={s['preemptions']}")
        return
    rts = _to_rts(pairs)
    respect = args.arrival_rate > 0
    if planner:
        out = run_planner(eng, rts, model, discipline, args.max_batch,
                          respect)
    else:
        pol = make(args.policy, model=model, max_batch=args.max_batch)
        # a policy that carries its own discipline (dynamic-chunk) wins
        # over the flag — same convention as benchmarks/bench_goodput
        discipline = getattr(pol, "discipline", None) or discipline
        out = eng.run_policy(rts, pol, discipline=discipline, model=model,
                             respect_arrivals=respect)
    met = sum(v["met"] for v in out.values())
    tot = sum(v["e2e"] for v in out.values())
    npre = sum(v["preemptions"] for v in out.values())
    print(f"policy={args.policy} discipline={discipline!r} arch={cfg.name} "
          f"G={met / tot if tot else 0:.4f} attainment={met}/{len(out)} "
          f"avg={tot / len(out):.2f}s preemptions={npre}")


if __name__ == "__main__":
    main()
