"""Serving launcher: engine + SLO-aware scheduler on a workload file or a
synthetic mixed workload.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --n 12 --policy slo|fcfs
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.core import SAParams, SLOAwareScheduler
from repro.core.profiler import LatencyProfiler
from repro.core.slo import SLO, Request
from repro.data.synthetic import CHAT_SLO, CODE_SLO
from repro.engine.engine import Engine
from repro.engine.request import RuntimeRequest
from repro.models import init_params


def synth_workload(n, vocab, rng, scale=1.0):
    rts = []
    for i in range(n):
        code = i % 2 == 0
        slo = SLO(e2e=8.0 * scale) if code else SLO(ttft=3.0 * scale,
                                                    tpot=0.5 * scale)
        lin = int(rng.integers(16, 96))
        lout = int(rng.integers(8, 48))
        rts.append(RuntimeRequest(
            request=Request(req_id=i, task_type="code" if code else "chat",
                            input_len=lin, slo=slo, output_len=lout),
            prompt_tokens=rng.integers(0, vocab, lin).astype(np.int32),
            max_new_tokens=lout))
    return rts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--n", type=int, default=12)
    ap.add_argument("--policy", choices=("slo", "fcfs"), default="slo")
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.uses_extra_embeds:
        raise SystemExit("VLM serving needs an embedding frontend; use the "
                         "dry-run for qwen2-vl shapes")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    rts = synth_workload(args.n, cfg.vocab_size, rng)

    prof = LatencyProfiler()
    warm = Engine(cfg, params, max_slots=args.max_batch, max_seq_len=256,
                  profiler=prof)
    warm.run_fcfs(synth_workload(6, cfg.vocab_size, rng))
    model = prof.fit()

    eng = Engine(cfg, params, max_slots=args.max_batch, max_seq_len=256)
    if args.policy == "fcfs":
        out = eng.run_fcfs(rts)
    else:
        reqs = [rt.request for rt in rts]
        for rt, r in zip(rts, reqs):
            r.predicted_output_len = rt.max_new_tokens
        sched = SLOAwareScheduler(model, num_instances=1,
                                  max_batch=args.max_batch,
                                  sa_params=SAParams(seed=0))
        outcome = sched.schedule(reqs)
        by_id = {rt.req_id: rt for rt in rts}
        planned = [[by_id[r.req_id] for r in b]
                   for b in outcome.queues[0].batches]
        out = eng.run_planned(planned)
    met = sum(v["met"] for v in out.values())
    tot = sum(v["e2e"] for v in out.values())
    print(f"policy={args.policy} arch={cfg.name} "
          f"G={met / tot if tot else 0:.4f} attainment={met}/{len(out)} "
          f"avg={tot / len(out):.2f}s")


if __name__ == "__main__":
    main()
