"""Serving launcher: engine + SLO-aware scheduler on a workload file or a
synthetic mixed workload.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --n 12 --policy slo|fcfs|slo-preempt [--discipline stall|chunked:32]

Policies and disciplines are resolved through the v2 registry
(``repro.core.policies.make``): ``slo`` plans batches offline with
Algorithm 1/2 and dispatches them; ``fcfs`` and ``slo-preempt`` drive the
engine's admission loop directly (the latter may evict running requests
when a tight-SLO arrival would otherwise miss — KV is recomputed).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.core import SAParams, SLOAwareScheduler
from repro.core.policies import make, make_discipline
from repro.core.profiler import LatencyProfiler
from repro.core.slo import SLO, Request
from repro.data.synthetic import CHAT_SLO, CODE_SLO
from repro.engine.engine import Engine
from repro.engine.request import RuntimeRequest
from repro.models import init_params


def synth_workload(n, vocab, rng, scale=1.0, arrival_rate=0.0):
    rts = []
    t = 0.0
    for i in range(n):
        code = i % 2 == 0
        slo = SLO(e2e=8.0 * scale) if code else SLO(ttft=3.0 * scale,
                                                    tpot=0.5 * scale)
        lin = int(rng.integers(16, 96))
        lout = int(rng.integers(8, 48))
        if arrival_rate > 0:
            t += float(rng.exponential(1.0 / arrival_rate))
        rts.append(RuntimeRequest(
            request=Request(req_id=i, task_type="code" if code else "chat",
                            input_len=lin, slo=slo, output_len=lout,
                            arrival_time=t),
            prompt_tokens=rng.integers(0, vocab, lin).astype(np.int32),
            max_new_tokens=lout))
    return rts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--n", type=int, default=12)
    ap.add_argument("--policy", choices=("slo", "fcfs", "slo-preempt"),
                    default="slo")
    ap.add_argument("--discipline", default="stall",
                    help="stall | chunked | chunked:<size>")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="requests/s; 0 = all submitted at t=0")
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.uses_extra_embeds:
        raise SystemExit("VLM serving needs an embedding frontend; use the "
                         "dry-run for qwen2-vl shapes")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    rts = synth_workload(args.n, cfg.vocab_size, rng,
                         arrival_rate=args.arrival_rate)
    discipline = make_discipline(args.discipline)

    prof = LatencyProfiler()
    warm = Engine(cfg, params, max_slots=args.max_batch, max_seq_len=256,
                  profiler=prof)
    warm.run_fcfs(synth_workload(6, cfg.vocab_size, rng))
    model = prof.fit()

    eng = Engine(cfg, params, max_slots=args.max_batch, max_seq_len=256)
    respect = args.arrival_rate > 0
    if args.policy == "slo":
        reqs = [rt.request for rt in rts]
        for rt, r in zip(rts, reqs):
            r.predicted_output_len = rt.max_new_tokens
        sched = SLOAwareScheduler(model, num_instances=1,
                                  max_batch=args.max_batch,
                                  sa_params=SAParams(seed=0))
        outcome = sched.schedule(reqs)
        # score the plan under both disciplines before dispatch
        for disc in ("stall", f"chunked:{discipline.chunk_size or 32}"):
            ev = sched.evaluate_plan(outcome, discipline=disc)
            print(f"plan under {disc:<12}: predicted G={ev.G:.4f} "
                  f"attainment={ev.attainment:.2f}")
        by_id = {rt.req_id: rt for rt in rts}
        planned = [[by_id[r.req_id] for r in b]
                   for b in outcome.queues[0].batches]
        out = eng.run_planned(planned, discipline=discipline, model=model,
                              respect_arrivals=respect)
    else:
        pol = make(args.policy, model=model, max_batch=args.max_batch)
        out = eng.run_policy(rts, pol, discipline=discipline, model=model,
                             respect_arrivals=respect)
    met = sum(v["met"] for v in out.values())
    tot = sum(v["e2e"] for v in out.values())
    npre = sum(v["preemptions"] for v in out.values())
    print(f"policy={args.policy} discipline={discipline!r} arch={cfg.name} "
          f"G={met / tot if tot else 0:.4f} attainment={met}/{len(out)} "
          f"avg={tot / len(out):.2f}s preemptions={npre}")


if __name__ == "__main__":
    main()
