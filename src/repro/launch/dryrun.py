"""Multi-pod dry-run: lower + compile every (arch × input shape × mesh).

MUST set the host-device override before ANY other import — jax locks the
device count on first initialization.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse        # noqa: E402
import json            # noqa: E402
import re              # noqa: E402
import time            # noqa: E402
from functools import partial  # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ASSIGNED, get_config  # noqa: E402
from repro.distributed.sharding import (ParallelismConfig, cache_specs,  # noqa: E402
                                        make_ctx, param_specs)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.cache import init_cache  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.models.model import forward_decode, forward_full, init_params  # noqa: E402
from repro.train import optimizer as opt  # noqa: E402
from repro.train.train_step import train_step  # noqa: E402

# StreamingLLM-style window used for full-attention archs at 500k decode
STREAM_WINDOW = 8192

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


# --------------------------------------------------------------- inputs
def input_specs(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    sh = SHAPES[shape_name]
    b, s = sh["batch"], sh["seq"]
    i32 = jnp.int32
    bf16 = jnp.dtype(cfg.dtype)
    out = {}
    if sh["kind"] in ("train", "prefill"):
        if cfg.uses_extra_embeds:
            out["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), bf16)
            out["positions"] = jax.ShapeDtypeStruct((b, s, 3), i32)
        elif cfg.num_codebooks:
            out["tokens"] = jax.ShapeDtypeStruct((b, s, cfg.num_codebooks), i32)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if sh["kind"] == "train":
            if cfg.num_codebooks:
                out["labels"] = jax.ShapeDtypeStruct(
                    (b, s, cfg.num_codebooks), i32)
            else:
                out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    else:  # decode
        if cfg.uses_extra_embeds:
            out["embeds"] = jax.ShapeDtypeStruct((b, 1, cfg.d_model), bf16)
            out["positions"] = jax.ShapeDtypeStruct((b, 1, 3), i32)
        elif cfg.num_codebooks:
            out["tokens"] = jax.ShapeDtypeStruct((b, 1, cfg.num_codebooks), i32)
        else:
            out["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
    return out


def decode_window(cfg: ModelConfig, shape_name: str) -> int:
    if shape_name == "long_500k" and not cfg.sliding_window \
            and cfg.family not in ("ssm",):
        return STREAM_WINDOW     # windowed-KV serving mode (DESIGN.md §4)
    return 0


def cache_struct(cfg, shape_name, kv_quant: bool = False):
    sh = SHAPES[shape_name]
    window = decode_window(cfg, shape_name)
    return jax.eval_shape(partial(init_cache, cfg, sh["batch"], sh["seq"],
                                  window=window, quantized=kv_quant))


# --------------------------------------------------------------- steps
def build_step(cfg: ModelConfig, shape_name: str, mesh, par,
               kv_quant: bool = False):
    """Returns (fn, example_args, in_shardings, out_shardings)."""
    sh = SHAPES[shape_name]
    ctx = make_ctx(mesh, par)
    params_sds = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    pspecs = param_specs(params_sds, cfg, mesh, par)
    psh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    ins = input_specs(cfg, shape_name)
    dp = par.dp_axes
    bspec = dp if sh["batch"] % _axes_size(mesh, dp) == 0 else None

    def in_shard(name, sds):
        extra = (None,) * (len(sds.shape) - 1)
        return NamedSharding(mesh, P(bspec, *extra))

    in_sh = {k: in_shard(k, v) for k, v in ins.items()}

    if sh["kind"] == "train":
        ocfg = opt.AdamWConfig()
        ostate_sds = jax.eval_shape(opt.init, params_sds)
        osh = jax.tree.map(
            lambda _: None, ostate_sds)
        # optimizer state mirrors param sharding (mu/nu same shapes)
        osh = opt.AdamWState(step=NamedSharding(mesh, P()),
                             mu=psh, nu=psh)

        def fn(params, opt_state, batch):
            return train_step(cfg, ocfg, params, opt_state, batch, ctx=ctx,
                              remat=True)

        args = (params_sds, ostate_sds, ins)
        in_shardings = (psh, osh, in_sh)
        out_shardings = (psh, osh, None)
        return fn, args, in_shardings, out_shardings

    if sh["kind"] == "prefill":
        csds = cache_struct(cfg, shape_name, kv_quant)
        cspecs = cache_specs(csds, cfg, mesh, par, sh["batch"])
        csh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), cspecs,
                           is_leaf=lambda x: isinstance(x, P))

        def fn(params, cache, batch):
            logits, cache, _ = forward_full(
                params, cfg, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"), positions=batch.get("positions"),
                cache=cache, ctx=ctx, last_only=True)
            return logits, cache

        args = (params_sds, csds, ins)
        return fn, args, (psh, csh, in_sh), (None, csh)

    # decode
    csds = cache_struct(cfg, shape_name, kv_quant)
    cspecs = cache_specs(csds, cfg, mesh, par, sh["batch"])
    csh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), cspecs,
                       is_leaf=lambda x: isinstance(x, P))

    def fn(params, cache, batch):
        logits, cache = forward_decode(
            params, cfg, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"), positions=batch.get("positions"),
            cache=cache, ctx=ctx)
        return logits, cache

    args = (params_sds, csds, ins)
    return fn, args, (psh, csh, in_sh), (None, csh)


def _axes_size(mesh, axes):
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


# --------------------------------------------------------------- analysis
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|f64|s64|c64)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "f64": 8, "s64": 8, "c64": 8}


def collective_bytes(hlo_text: str):
    """Per-device bytes moved through each collective kind (output-shape
    proxy), parsed from the post-SPMD HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for kind in _COLLECTIVES:
            # match the op name: `= TYPE[...] all-gather(` or `-start(`
            if re.search(rf"\s{kind}(-start)?\(", stripped):
                lhs = stripped.split("=")[0] + "=" + \
                    stripped.split("=", 1)[1].split(kind)[0]
                nbytes = 0
                for m in _SHAPE_RE.finditer(lhs):
                    dims = m.group(2)
                    n = 1
                    if dims:
                        for d in dims.split(","):
                            n *= int(d)
                    nbytes += n * _BYTES[m.group(1)]
                out[kind] += nbytes
                counts[kind] += 1
                break
    return out, counts


def analyze(compiled, lowered_text=None):
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll, counts = collective_bytes(hlo)
    return {
        "flops_per_device": cost.get("flops", 0.0) if cost else 0.0,
        "bytes_accessed_per_device": cost.get("bytes accessed", 0.0)
        if cost else 0.0,
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        "collective_bytes_per_device": coll,
        "collective_counts": counts,
    }


# --------------------------------------------------------------- driver
def run_one(arch: str, shape_name: str, multi_pod: bool,
            par: ParallelismConfig = None, save: bool = True,
            verbose: bool = True, optimized: bool = False,
            out_dir: str = None):
    """optimized=True enables the §Perf winners: sequence-parallel
    attention constraints + (2D) expert-parallel MoE."""
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    if par is None:
        # FSDP for training (params+optimizer sharded everywhere).
        # Serving keeps weights tp-resident (no per-step weight gathers)
        # unless the tp-sharded weights alone would not fit HBM (dbrx).
        tp_resident_gb = cfg.param_count() * 2 / 16 / 2**30
        par = ParallelismConfig(
            dp_axes=("pod", "data") if multi_pod else ("data",),
            fsdp=(SHAPES[shape_name]["kind"] == "train"
                  or tp_resident_gb > 8.0),
            expert_parallel=optimized,
            attn_sharding="auto" if optimized else "none")
    fn, args, in_sh, out_sh = build_step(cfg, shape_name, mesh, par)
    t0 = time.time()
    # NamedShardings carry the mesh; shard_map sites receive it via ctx.
    lowered = jax.jit(fn, in_shardings=in_sh,
                      out_shardings=out_sh).lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    rec = analyze(compiled)
    rec.update(arch=arch, shape=shape_name,
               mesh="2x16x16" if multi_pod else "16x16",
               lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
               param_count=cfg.param_count(),
               active_param_count=cfg.active_param_count())
    if verbose:
        mem_gb = rec["peak_bytes"] / 2**30
        arg_gb = rec["argument_bytes"] / 2**30
        print(f"[dryrun] {arch} × {shape_name} × {rec['mesh']}: "
              f"compile {t_compile:.1f}s, peak {mem_gb:.2f} GiB/dev, "
              f"args {arg_gb:.2f} GiB/dev, "
              f"flops/dev {rec['flops_per_device']:.3g}")
        print("  memory_analysis:", compiled.memory_analysis())
        ca = compiled.cost_analysis()
        print("  cost_analysis: flops=%.4g bytes=%.4g" % (
            ca.get("flops", 0), ca.get("bytes accessed", 0)))
        print("  collectives:", {k: f"{v/2**20:.1f}MiB"
                                 for k, v in
                                 rec["collective_bytes_per_device"].items()
                                 if v})
    if save:
        d = out_dir or RESULTS_DIR
        os.makedirs(d, exist_ok=True)
        tag = f"{arch}_{shape_name}_{rec['mesh']}".replace("/", "-")
        with open(os.path.join(d, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None,
                    help="input-shape id or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="enable §Perf winners (seq-par attn, EP MoE)")
    ap.add_argument("--out-dir", default=None)
    args = ap.parse_args()
    archs = ASSIGNED if args.arch in (None, "all") else [args.arch]
    shapes = list(SHAPES) if args.shape in (None, "all") else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_one(arch, shape, mp, optimized=args.optimized,
                            out_dir=args.out_dir)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)[:200]))
                    print(f"[dryrun] FAIL {arch} × {shape} mp={mp}: {e}")
    if failures:
        print(f"{len(failures)} FAILURES")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("dry-run: all combinations lowered and compiled.")


if __name__ == "__main__":
    main()
