"""Training launcher.

Local run (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 20 --batch 8 --seq 128

Production mesh dry-run of the full config (no allocation):
  handled by repro.launch.dryrun (train_4k shape).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.data.synthetic import token_stream
from repro.models import init_params
from repro.train import optimizer as opt
from repro.train.train_step import train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced variant (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None, help="checkpoint path prefix")
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--resume", default=None)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1))
    state = opt.init(params)
    start = 0
    if args.resume:
        from repro.train.checkpoint import restore
        params, state, start = restore(args.resume, params, state)
        print(f"resumed from {args.resume} at step {start}")
    step_fn = jax.jit(lambda p, s, b: train_step(cfg, ocfg, p, s, b))

    rng = np.random.default_rng(0)
    kw_embeds = cfg.uses_extra_embeds
    nc = cfg.num_codebooks
    t0 = time.time()
    for step in range(args.steps):
        if kw_embeds:
            batch = {
                "embeds": jnp.asarray(rng.normal(
                    0, 1, (args.batch, args.seq, cfg.d_model)), jnp.float32),
                "labels": jnp.asarray(rng.integers(
                    0, cfg.vocab_size, (args.batch, args.seq)), jnp.int32),
            }
        elif nc:
            toks = rng.integers(0, cfg.vocab_size,
                                (args.batch, args.seq, nc))
            batch = {"tokens": jnp.asarray(toks, jnp.int32),
                     "labels": jnp.asarray(toks, jnp.int32)}
        else:
            toks = token_stream(args.seq, cfg.vocab_size, seed=step,
                                batch=args.batch)
            batch = {"tokens": jnp.asarray(toks),
                     "labels": jnp.asarray(toks)}
        params, state, metrics = step_fn(params, state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                  f"({(time.time() - t0) / (step + 1):.2f}s/step)")
        if args.ckpt and (step + 1) % args.save_every == 0:
            from repro.train.checkpoint import save
            save(args.ckpt, params, state, step=start + step + 1,
                 meta={"arch": cfg.name})


if __name__ == "__main__":
    main()
