"""PartitionSpec rules for params, optimizer state, inputs and caches.

Mesh axes:
  * ``model`` (tp) — shards attention heads, FFN width, experts, vocab.
  * ``data`` / ``pod`` (dp) — shard the batch; in FSDP mode they also shard
    the non-tp dimension of every large weight (ZeRO-3 style).

Rules are name+shape driven over the params pytree produced by
``init_params`` — one place to read the whole distribution strategy.

SSM blocks: the Mamba2 in_proj concatenates (z | x | B | C | dt) whose
boundaries do not align with a 16-way column shard, so SSM weights shard
over the FSDP axis only (noted in DESIGN.md §5); SSM activations are data
parallel.  Attention/MoE layers carry the tensor-parallel dimension.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.moe import ShardingCtx


@dataclasses.dataclass(frozen=True)
class ParallelismConfig:
    tp_axis: str = "model"
    dp_axes: Tuple[str, ...] = ("data",)     # ("pod","data") multi-pod
    fsdp: bool = True                         # shard weights over dp too
    # shard the KV-cache sequence dim over tp when heads cannot shard
    seq_sharded_cache: bool = True
    # MoE expert-parallel all-to-all instead of weight gathering (§Perf)
    expert_parallel: bool = False
    # "auto": sequence-parallel attention activations (§Perf)
    attn_sharding: str = "none"

    @property
    def fsdp_spec(self):
        return self.dp_axes if self.fsdp else None


def _div(n: int, mesh: Mesh, axes) -> bool:
    """Is n divisible by the product of the named mesh axes?"""
    if axes is None:
        return False
    if isinstance(axes, str):
        axes = (axes,)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return n % size == 0 and n >= size


def _pspec(shape, mesh, par, *wants):
    """Build a PartitionSpec assigning wants[i] to dim i when divisible."""
    spec = []
    for dim, want in zip(shape, wants):
        if want is not None and _div(dim, mesh, want):
            spec.append(want)
        else:
            spec.append(None)
    return P(*spec)


def param_specs(params, cfg: ModelConfig, mesh: Mesh,
                par: ParallelismConfig):
    """PartitionSpec pytree matching the params structure."""
    tp = par.tp_axis
    fs = par.fsdp_spec

    def attn_block(block):
        out = {}
        for k, v in block.items():
            if k in ("attn_norm", "mlp_norm"):
                out[k] = jax.tree.map(lambda a: P(), v)
            elif k == "attn":
                out[k] = {}
                for name, w in v.items():
                    if name == "wq":
                        out[k][name] = _pspec(w.shape, mesh, par, fs, tp)
                    elif name in ("wk", "wv"):
                        out[k][name] = _pspec(w.shape, mesh, par, fs, tp)
                    elif name == "wo":
                        out[k][name] = _pspec(w.shape, mesh, par, tp, fs)
                    elif name in ("w_dkv", "w_krope"):
                        out[k][name] = _pspec(w.shape, mesh, par, fs, None)
                    elif name in ("w_uk", "w_uv"):
                        out[k][name] = _pspec(w.shape, mesh, par, None, tp)
                    else:               # q_norm, k_norm, kv_norm
                        out[k][name] = P()
            elif k == "mlp":
                out[k] = {n: _pspec(w.shape, mesh, par,
                                    *( (fs, tp) if n != "w_down" else (tp, fs)))
                          for n, w in v.items()}
            elif k == "moe":
                out[k] = {}
                for n, w in v.items():
                    if n == "router":
                        out[k][n] = _pspec(w.shape, mesh, par, fs, None)
                    elif n == "shared":
                        out[k][n] = {m: _pspec(x.shape, mesh, par,
                                               *((fs, tp) if m != "w_down"
                                                 else (tp, fs)))
                                     for m, x in w.items()}
                    elif n == "w_down":   # [E, F, D]
                        # fsdp on the ff dim: aligns with 2D expert-parallel
                        # decode (zero weight movement; §Perf iteration 5)
                        out[k][n] = _pspec(w.shape, mesh, par, tp, fs, None)
                    else:                 # w_gate / w_up [E, D, F]
                        out[k][n] = _pspec(w.shape, mesh, par, tp, None, fs)
            else:
                out[k] = jax.tree.map(lambda a: P(), v)
        return out

    def ssm_block(block):
        out = {"norm": jax.tree.map(lambda a: P(), block["norm"]), "mamba": {}}
        for n, w in block["mamba"].items():
            if w.ndim == 2:
                out["mamba"][n] = _pspec(w.shape, mesh, par, fs, None)
            else:
                out["mamba"][n] = P()
        return out

    specs = {}
    emb = params["embed"]
    # Embeddings shard on the vocab dim ONLY (never FSDP on d_model):
    # row-sharding the lm_head over the data axis makes GSPMD replicate the
    # batch and all-reduce full [B,S,V] logits (§Perf iteration 3) — the
    # tp-sharded table is small enough to keep resident.
    if emb.ndim == 3:      # audio [K, V, D]
        specs["embed"] = _pspec(emb.shape, mesh, par, None, tp, None)
    else:
        specs["embed"] = _pspec(emb.shape, mesh, par, tp, None)
    if "lm_head" in params:
        lh = params["lm_head"]
        if lh.ndim == 3:
            specs["lm_head"] = _pspec(lh.shape, mesh, par, None, None, tp)
        else:
            specs["lm_head"] = _pspec(lh.shape, mesh, par, None, tp)
    specs["final_norm"] = jax.tree.map(lambda a: P(), params["final_norm"])
    if "shared_block" in params:
        specs["shared_block"] = attn_block(params["shared_block"])
    specs["layers"] = []
    for layer in params["layers"]:
        if not layer:
            specs["layers"].append({})
        elif "mamba" in layer:
            specs["layers"].append(ssm_block(layer))
        else:
            specs["layers"].append(attn_block(layer))
    return specs


def cache_specs(cache_shapes, cfg: ModelConfig, mesh: Mesh,
                par: ParallelismConfig, batch: int):
    """PartitionSpec pytree for a decode cache (from cache_spec shapes).

    Handles both layouts:
      * dense per-slot ``[B, L, kv, hd]`` caches (``init_cache``);
      * block-paged pools (``init_paged_cache``) — detected by the presence
        of ``block_tables`` in the shapes pytree.  Page arrays
        ``[num_blocks, block_size, kv, hd]`` shard on the kv-head axis
        (dim 2) when the head count divides the tp axis; ``pos`` and
        ``block_tables`` stay replicated so the host-side BlockPool,
        prefix-reuse, and CoW logic never see a sharded array.
    """
    if "block_tables" in cache_shapes:
        return _paged_cache_specs(cache_shapes, mesh, par)
    tp = par.tp_axis
    dp = par.dp_axes
    batch_ok = _div(batch, mesh, dp)
    bspec = dp if batch_ok else None

    def layer_spec(layer):
        out = {}
        for k, v in layer.items():
            if k in ("k", "v", "k_scale", "v_scale"):  # [B, L, kv, hd|1]
                heads = v.shape[2]
                # prefer kv-head sharding; fall back to sequence sharding
                # (kv heads rarely divide a 16-way tp axis)
                hspec = tp if _div(heads, mesh, tp) else None
                seq = tp if (hspec is None and par.seq_sharded_cache and
                             _div(v.shape[1], mesh, tp)) else None
                out[k] = P(bspec, seq, hspec, None)
            elif k in ("ckv", "kpe"):  # [B, L, rank]
                seq = tp if (par.seq_sharded_cache and
                             _div(v.shape[1], mesh, tp)) else None
                out[k] = P(bspec, seq, None)
            elif k == "conv":          # [B, K-1, C]
                out[k] = P(bspec, None, None)
            elif k == "ssm":           # [B, nh, hd, ds]
                out[k] = P(bspec, None, None, None)
        return out

    return {"pos": P(bspec),
            "layers": [layer_spec(l) for l in cache_shapes["layers"]]}


def _paged_cache_specs(cache_shapes, mesh: Mesh, par: ParallelismConfig):
    """Specs for the block-paged pool layout (see cache_specs docstring).

    GQA kv-head groups stay whole per shard: sharding dim 2 of
    ``[num_blocks, block_size, kv, hd]`` by the tp axis puts kv/tp full
    heads on each device, and the query heads of each group shard the
    same way through ``wq``'s column shard — no cross-device attention.
    Per-slot SSM state (``conv``/``ssm``, leading dim = max_slots) and all
    host-consulted arrays (``pos``, ``block_tables``) remain replicated.
    """
    tp = par.tp_axis

    def layer_spec(layer):
        out = {}
        for k, v in layer.items():
            if k in ("k", "v"):            # [N, P, kv, hd]
                hspec = tp if _div(v.shape[2], mesh, tp) else None
                out[k] = P(None, None, hspec, None)
            elif k in ("k_scale", "v_scale"):  # [N, P, kv, 1]
                hspec = tp if _div(v.shape[2], mesh, tp) else None
                out[k] = P(None, None, hspec, None)
            elif k in ("ckv", "kpe"):      # [N, P, rank] — latent, no heads
                out[k] = P(None, None, None)
            elif k == "conv":              # [max_slots, K-1, C]
                out[k] = P(None, None, None)
            elif k == "ssm":               # [max_slots, nh, hd, ds]
                out[k] = P(None, None, None, None)
        return out

    return {"pos": P(None),
            "block_tables": P(None, None),
            "layers": [layer_spec(l) for l in cache_shapes["layers"]]}


def input_sharding(cfg: ModelConfig, mesh: Mesh, par: ParallelismConfig,
                   batch: int):
    dp = par.dp_axes if _div(batch, mesh, par.dp_axes) else None
    return dp


def make_ctx(mesh: Mesh, par: ParallelismConfig) -> ShardingCtx:
    return ShardingCtx(mesh=mesh, dp_axes=par.dp_axes, tp_axis=par.tp_axis,
                       expert_parallel=par.expert_parallel,
                       attn_sharding=par.attn_sharding,
                       fsdp_axes=par.dp_axes if par.fsdp else ())


def named(mesh: Mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))
