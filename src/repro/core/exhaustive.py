"""Strawman exhaustive search (paper §4.3): every permutation × every batch
composition.  O(N! · 2^N) — only usable for tiny N; exists as the oracle the
annealer is validated against (paper reports ≤1.0% degradation vs this).
"""
from __future__ import annotations

import itertools
from typing import Tuple

import numpy as np

from repro.core.objective import evaluate


def _compositions(n: int, max_batch: int):
    """All ordered compositions of n with parts <= max_batch."""
    if n == 0:
        yield ()
        return
    for first in range(1, min(max_batch, n) + 1):
        for rest in _compositions(n - first, max_batch):
            yield (first,) + rest


def exhaustive_search(arrays: dict, model, max_batch: int
                      ) -> Tuple[np.ndarray, np.ndarray, float, int]:
    """Returns (perm, batch_id, G, evaluations)."""
    n = len(arrays["input_len"])
    best = (None, None, -1.0)
    evals = 0
    comps = list(_compositions(n, max_batch))
    for perm in itertools.permutations(range(n)):
        perm = np.array(perm, np.int64)
        for comp in comps:
            batch_id = np.repeat(np.arange(len(comp)), comp)
            g = evaluate(arrays, model, perm, batch_id).G
            evals += 1
            if g > best[2]:
                best = (perm.copy(), batch_id.copy(), g)
    return best[0], best[1], best[2], evals
