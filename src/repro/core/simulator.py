"""Offline execution wrappers over the unified discrete-event core.

Benchmarks compare scheduling policies by *executing* schedules against
actual output lengths (the planner only saw predictions) using the fitted
latency model plus optional multiplicative noise — mirroring the paper's
experimental gap between predicted and measured latencies.

All execution loops live in :mod:`repro.core.events` (one token-granular
simulator, engine-faithful first-token accounting); this module keeps the
historical entry points as thin wrappers:

  * ``run_planned``  — the SLO-aware lock-step path: the scheduler's
    batches run sequentially per instance (a batch is admitted together
    and the next batch waits until the previous one drained).
  * ``run_priority_continuous`` — planned priority order fed to a
    continuously-batching engine (the paper's actual dispatch, §5.1).
  * ``run_fcfs_continuous`` — the vLLM-like FCFS baseline.
  * ``run_multi_instance`` — planned batches across parallel instances.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.events import (AdmissionPolicy, FCFSPolicy,  # noqa: F401
                               PlannedPolicy, SimResult,
                               SLOReannealPolicy, simulate)
from repro.core.latency_model import LinearLatencyModel
from repro.core.policies import ExecutionDiscipline
from repro.core.slo import Request


def run_planned(batches: Sequence[Sequence[Request]],
                model: LinearLatencyModel,
                noise_sigma: float = 0.0,
                rng: Optional[np.random.Generator] = None,
                inter_batch_gap: float = 1e-4,
                discipline: "str | ExecutionDiscipline | None" = None
                ) -> SimResult:
    """Execute planned batches sequentially on one instance."""
    batches = [list(b) for b in batches if len(b)]
    ordered = [r for b in batches for r in b]
    max_batch = max((len(b) for b in batches), default=1)
    return simulate(ordered, model, max_batch, PlannedPolicy(batches),
                    noise_sigma=noise_sigma, rng=rng,
                    respect_arrivals=False, inter_batch_gap=inter_batch_gap,
                    discipline=discipline)


def run_multi_instance(queues, model: LinearLatencyModel,
                       noise_sigma: float = 0.0,
                       seed: int = 0) -> SimResult:
    """Instances run in parallel; each executes its planned batches."""
    out = SimResult({}, {}, {}, {})
    for q in queues:
        rng = np.random.default_rng(seed + 1000 * q.instance_id)
        out = out.merged_with(
            run_planned(q.batches, model, noise_sigma, rng))
    return out


def run_priority_continuous(batches: Sequence[Sequence[Request]],
                            model: LinearLatencyModel,
                            max_batch: int,
                            noise_sigma: float = 0.0,
                            rng: Optional[np.random.Generator] = None
                            ) -> SimResult:
    """Execute an SLO-aware plan the way the paper does (§5.1): batches are
    *submitted* in priority order 0.1 ms apart, but the engine continuously
    admits from the queue as slots free up — i.e. continuous batching with
    the planned priority order as the arrival order."""
    ordered = [r for batch in batches for r in batch]
    return run_fcfs_continuous(ordered, model, max_batch,
                               noise_sigma=noise_sigma, rng=rng)


def run_fcfs_continuous(requests: Sequence[Request],
                        model: LinearLatencyModel,
                        max_batch: int,
                        noise_sigma: float = 0.0,
                        rng: Optional[np.random.Generator] = None
                        ) -> SimResult:
    """vLLM-like FCFS + continuous batching baseline on one instance."""
    return simulate(requests, model, max_batch, "fcfs",
                    noise_sigma=noise_sigma, rng=rng,
                    respect_arrivals=False)
