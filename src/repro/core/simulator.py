"""Discrete-event execution simulator.

Benchmarks compare scheduling policies by *executing* schedules against
actual output lengths (the planner only saw predictions) using the fitted
latency model plus optional multiplicative noise — mirroring the paper's
experimental gap between predicted and measured latencies.

Two execution models:
  * ``run_planned``  — the SLO-aware path: the scheduler's batches run
    sequentially per instance (requests in a batch are dispatched together;
    a batch ends when its slowest member finishes).
  * ``run_fcfs_continuous`` — the vLLM-like baseline: FCFS admission with
    continuous batching at token granularity; prefills stall the running
    batch (non-chunked), decode steps take the max per-token time of the
    active set.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.latency_model import LinearLatencyModel
from repro.core.slo import Request, meets_slo


@dataclasses.dataclass
class SimResult:
    e2e: Dict[int, float]
    ttft: Dict[int, float]
    tpot: Dict[int, float]
    met: Dict[int, bool]

    @property
    def n(self):
        return len(self.e2e)

    @property
    def attainment(self) -> float:
        return sum(self.met.values()) / max(self.n, 1)

    @property
    def total_latency(self) -> float:
        return sum(self.e2e.values())

    @property
    def avg_latency(self) -> float:
        return self.total_latency / max(self.n, 1)

    @property
    def G(self) -> float:
        t = self.total_latency
        return sum(self.met.values()) / t if t > 0 else 0.0

    def merged_with(self, other: "SimResult") -> "SimResult":
        return SimResult(e2e={**self.e2e, **other.e2e},
                         ttft={**self.ttft, **other.ttft},
                         tpot={**self.tpot, **other.tpot},
                         met={**self.met, **other.met})


def _noise(rng: Optional[np.random.Generator], sigma: float) -> float:
    if rng is None or sigma <= 0:
        return 1.0
    return float(np.exp(rng.normal(0.0, sigma)))


def run_planned(batches: Sequence[Sequence[Request]],
                model: LinearLatencyModel,
                noise_sigma: float = 0.0,
                rng: Optional[np.random.Generator] = None,
                inter_batch_gap: float = 1e-4) -> SimResult:
    """Execute planned batches sequentially on one instance."""
    clock = 0.0
    res = SimResult({}, {}, {}, {})
    for batch in batches:
        if not batch:
            continue
        b = len(batch)
        durs = []
        for r in batch:
            lo = r.output_len if r.output_len is not None \
                else r.planning_output_len()
            t_p = model.prefill_time(b, r.input_len) * _noise(rng, noise_sigma)
            t_d = model.decode_time(b, r.input_len, lo) * _noise(rng, noise_sigma)
            ttft = clock + t_p
            e2e = clock + t_p + t_d
            res.ttft[r.req_id] = ttft
            res.e2e[r.req_id] = e2e
            res.tpot[r.req_id] = t_d / max(lo, 1)
            res.met[r.req_id] = meets_slo(r, e2e, ttft, res.tpot[r.req_id])
            durs.append(t_p + t_d)
        clock += max(durs) + inter_batch_gap
    return res


def run_multi_instance(queues, model: LinearLatencyModel,
                       noise_sigma: float = 0.0,
                       seed: int = 0) -> SimResult:
    """Instances run in parallel; each executes its planned batches."""
    out = SimResult({}, {}, {}, {})
    for q in queues:
        rng = np.random.default_rng(seed + 1000 * q.instance_id)
        out = out.merged_with(
            run_planned(q.batches, model, noise_sigma, rng))
    return out


def run_priority_continuous(batches: Sequence[Sequence[Request]],
                            model: LinearLatencyModel,
                            max_batch: int,
                            noise_sigma: float = 0.0,
                            rng: Optional[np.random.Generator] = None
                            ) -> SimResult:
    """Execute an SLO-aware plan the way the paper does (§5.1): batches are
    *submitted* in priority order 0.1 ms apart, but the engine continuously
    admits from the queue as slots free up — i.e. continuous batching with
    the planned priority order as the arrival order."""
    ordered = [r for batch in batches for r in batch]
    return run_fcfs_continuous(ordered, model, max_batch,
                               noise_sigma=noise_sigma, rng=rng)


def run_fcfs_continuous(requests: Sequence[Request],
                        model: LinearLatencyModel,
                        max_batch: int,
                        noise_sigma: float = 0.0,
                        rng: Optional[np.random.Generator] = None
                        ) -> SimResult:
    """vLLM-like FCFS + continuous batching baseline on one instance."""
    res = SimResult({}, {}, {}, {})
    clock = 0.0
    pending = list(requests)
    active = []          # dicts: req, accum, remaining, ttft_time, start

    while pending or active:
        # admission: fill free slots; prefill stalls the batch
        admitted = []
        while pending and len(active) + len(admitted) < max_batch:
            admitted.append(pending.pop(0))
        if admitted:
            b = len(admitted)
            pf = [model.prefill_time(b, r.input_len) * _noise(rng, noise_sigma)
                  for r in admitted]
            clock += max(pf)
            for r in admitted:
                lo = r.output_len if r.output_len is not None \
                    else r.planning_output_len()
                active.append({"req": r, "accum": r.input_len,
                               "remaining": max(int(lo), 1),
                               "ttft": clock, "gen": 0})
        if not active:
            continue
        # one decode iteration for the whole active set
        b = len(active)
        step = max(model.per_token_decode_time(b, a["accum"])
                   for a in active) * _noise(rng, noise_sigma)
        clock += step
        done = []
        for a in active:
            a["accum"] += 1
            a["gen"] += 1
            a["remaining"] -= 1
            if a["remaining"] <= 0:
                done.append(a)
        for a in done:
            active.remove(a)
            r = a["req"]
            res.ttft[r.req_id] = a["ttft"]
            res.e2e[r.req_id] = clock
            res.tpot[r.req_id] = (clock - a["ttft"]) / max(a["gen"], 1)
            res.met[r.req_id] = meets_slo(r, clock, a["ttft"],
                                          res.tpot[r.req_id])
    return res
