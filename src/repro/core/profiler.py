"""Request profiler (paper §4.2 and §5.1 'Workflows').

Three responsibilities:
  1. Collect (batch, length) → time samples from the engine and fit the
     linear latency model.
  2. Track per-task-type output lengths and model them as Gaussians
     (the paper's dynamic output-length predictor).
  3. Estimate the memory-utility constants μ and σ of Eq. 20.
"""
from __future__ import annotations

import dataclasses
import math
from collections import defaultdict
from typing import Dict, Optional

import numpy as np

from repro.core import latency_model as lm


@dataclasses.dataclass
class RunningGaussian:
    """Welford running mean/std."""
    n: int = 0
    mean: float = 0.0
    m2: float = 0.0

    def update(self, x: float):
        self.n += 1
        d = x - self.mean
        self.mean += d / self.n
        self.m2 += d * (x - self.mean)

    @property
    def std(self) -> float:
        return math.sqrt(self.m2 / self.n) if self.n > 1 else 0.0


class OutputLengthPredictor:
    """Per-task-type Gaussian over observed output lengths.

    ``predict`` draws from the fitted distribution (paper §5.1: 'the
    predictor generates a random integer based on this fitted
    distribution'); ``predict_mean`` returns the deterministic mean.
    Optionally a business-supplied prior (mean, std) seeds a type.
    """

    def __init__(self, priors: Optional[Dict[str, tuple]] = None, seed: int = 0):
        self._g: Dict[str, RunningGaussian] = defaultdict(RunningGaussian)
        self._rng = np.random.default_rng(seed)
        self._priors = dict(priors or {})

    def observe(self, task_type: str, output_len: int):
        self._g[task_type].update(float(output_len))

    def _dist(self, task_type: str):
        g = self._g.get(task_type)
        if g is not None and g.n >= 2:
            return g.mean, max(g.std, 1.0)
        if task_type in self._priors:
            return self._priors[task_type]
        return 128.0, 64.0          # cold-start default

    def predict(self, task_type: str) -> int:
        mu, sd = self._dist(task_type)
        return max(1, int(round(self._rng.normal(mu, sd))))

    def predict_mean(self, task_type: str) -> int:
        mu, _ = self._dist(task_type)
        return max(1, int(round(mu)))


class LatencyProfiler:
    """Accumulates engine timings and fits Eqs. 14–15."""

    def __init__(self):
        self.prefill_samples = []      # (b, l_i, t)
        self.decode_samples = []       # (b, l_a, tau)

    def observe_prefill(self, batch: int, input_len: int, seconds: float):
        self.prefill_samples.append((batch, input_len, seconds))

    def observe_decode(self, batch: int, accum_len: int, seconds: float):
        self.decode_samples.append((batch, accum_len, seconds))

    @property
    def ready(self) -> bool:
        return len(self.prefill_samples) >= 8 and len(self.decode_samples) >= 8

    def fit(self, nonneg: bool = False) -> lm.LinearLatencyModel:
        """``nonneg`` constrains every coefficient to be ≥ 0 — use it
        when the model feeds a simulator clock, where an extrapolated
        negative cost would make time run backwards."""
        if not self.ready:
            return lm.PAPER_TABLE2
        return lm.fit(self.prefill_samples, self.decode_samples,
                      nonneg=nonneg)


class MemoryModel:
    """Eq. 20: token_num(m) = m·μ/σ."""

    def __init__(self, total_memory: float, mu: float = 0.9,
                 sigma_per_token: float = 1.0):
        self.total = total_memory
        self.mu = mu
        self.sigma = sigma_per_token
        self._peak_ratios = []
        self._token_bytes = []

    def observe_run(self, peak_mem: float, avail_mem: float, tokens: int,
                    mem_used: float):
        self._peak_ratios.append(peak_mem / max(avail_mem, 1e-9))
        if tokens:
            self._token_bytes.append(mem_used / tokens)
        self.mu = float(np.mean(self._peak_ratios))
        if self._token_bytes:
            self.sigma = float(np.mean(self._token_bytes))

    def token_capacity(self, remaining: float) -> int:
        return int(remaining * self.mu / self.sigma)

    def tokens_to_memory(self, tokens: int) -> float:
        return tokens * self.sigma / self.mu
