"""Unified discrete-event serving core (scheduling API v2).

Historically this repo carried *three* hand-rolled continuous-batching
loops — ``online.simulate_online``, ``simulator.run_fcfs_continuous`` (and
friends), and the slot pool inside ``engine.Engine`` — whose token
accounting had silently diverged: the real engine samples the first token
from the prefill logits (TTFT *is* the first generated token, so a request
needs ``l_o - 1`` decode rounds), while both simulators required ``l_o``
decode rounds after TTFT and computed TPOT over a different token count.

This module is the single execution loop.  ``simulate`` is a
token-granularity discrete-event simulator driven by the two v2
scheduling abstractions from :mod:`repro.core.policies`:

  * :class:`~repro.core.policies.SchedulingPolicy` — at every scheduling
    event the policy receives a :class:`~repro.core.policies.SchedulerView`
    (pending queue, active set with generated/remaining/slack, instance
    id, clock, free slots) and returns a
    :class:`~repro.core.policies.Decision` with ``admit`` *and*
    ``preempt`` lists.  Preempted requests return to pending with KV
    discarded; re-admission re-prefills prompt + generated tokens
    (recompute cost charged).  Built-ins: ``FCFSPolicy``,
    ``PlannedPolicy``, ``SLOReannealPolicy``, ``SLOPreemptPolicy``.  The
    *same* policy objects drive the real engine (``Engine.run_policy``),
    so simulated and measured runs share one scheduling brain.
  * :class:`~repro.core.policies.ExecutionDiscipline` — emits each
    tick's :class:`~repro.core.policies.StepPlan`: one prefill span per
    staged (mid-prefill) request plus one decode item per active
    request.  ``StallingPrefill`` completes each prefill in one batched
    tick (running decodes stall behind it); ``ChunkedPrefill(n)``
    advances every staged prefill one chunk per tick, sharing the tick
    with the running decode round — the same plan/execute cycle
    ``Engine.execute_step`` runs, so simulated and real chunk timelines
    line up tick for tick.

The v1 ``AdmissionPolicy.select`` protocol still works through a
deprecation shim (see :mod:`repro.core.policies`); new code should
implement ``decide(view)``.

Execution semantics (engine-faithful — the fix for the historical drift):

  * prefill of an admitted set under ``StallingPrefill`` is batched: it
    completes at ``clock + max(member prefill times)``; that instant is
    TTFT *and* the first generated token (``gen = 1``); under
    ``ChunkedPrefill`` every staged request advances one chunk per tick
    (chunks priced back-to-back within the tick) and activates on its
    final chunk *before* that tick's decode round, so its first decode
    token rides the same tick; mid-prefill requests hold a slot but are
    excluded from decode rounds and the policies' active view;
  * each decode round generates one token for every active request and
    costs the max per-token decode time over the active set; a request
    finishes once ``gen == l_o`` — i.e. ``l_o - 1`` decode rounds after
    prefill (a request with ``l_o == 1`` finishes at prefill);
  * TPOT = (e2e − TTFT) / l_o, matching ``RuntimeRequest.metrics``;
  * a preempted request keeps its generated tokens and its original
    TTFT; on re-admission the prefill length is ``l_i + generated``
    (vLLM-style recompute) and the prefill emits the next token.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.annealing import SAParams
from repro.core.latency_model import LinearLatencyModel
# AdmissionPolicy/FCFSPolicy/PlannedPolicy/SLOReannealPolicy are
# re-exported here for v1 import compatibility (simulator.py, online.py)
from repro.core.policies import (AdmissionPolicy, ExecutionDiscipline,  # noqa: F401
                                 FCFSPolicy, PlannedPolicy,  # noqa: F401
                                 SchedulerView, SchedulingPolicy,
                                 SLOReannealPolicy,  # noqa: F401
                                 make_active_view, make_discipline,
                                 normalize_decision, resolve_policy)
from repro.core.slo import Request, meets_slo


@dataclasses.dataclass
class SimResult:
    e2e: Dict[int, float]
    ttft: Dict[int, float]
    tpot: Dict[int, float]
    met: Dict[int, bool]
    preemptions: Dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def n(self):
        return len(self.e2e)

    @property
    def attainment(self) -> float:
        return sum(self.met.values()) / max(self.n, 1)

    @property
    def total_latency(self) -> float:
        return sum(self.e2e.values())

    @property
    def avg_latency(self) -> float:
        return self.total_latency / max(self.n, 1)

    @property
    def n_preempted(self) -> int:
        return sum(self.preemptions.values())

    @property
    def G(self) -> float:
        t = self.total_latency
        return sum(self.met.values()) / t if t > 0 else 0.0

    def merged_with(self, other: "SimResult") -> "SimResult":
        return SimResult(e2e={**self.e2e, **other.e2e},
                         ttft={**self.ttft, **other.ttft},
                         tpot={**self.tpot, **other.tpot},
                         met={**self.met, **other.met},
                         preemptions={**self.preemptions,
                                      **other.preemptions})


def _noise(rng: Optional[np.random.Generator], sigma: float) -> float:
    if rng is None or sigma <= 0:
        return 1.0
    return float(np.exp(rng.normal(0.0, sigma)))


# ------------------------------------------------------------------- core
class _Instance:
    __slots__ = ("clock", "active", "prefilling", "dispatched")

    def __init__(self, clock: float = 0.0):
        self.clock = clock
        self.active: List[dict] = []
        # staged prefills advancing tick-by-tick under the step plan:
        # {"req", "done", "total", "gen0", "ttft0"} — the sim analog of
        # the engine's PREFILLING slots (they hold capacity but are
        # invisible to decode rounds and the policies' active view)
        self.prefilling: List[dict] = []
        self.dispatched = False


def simulate(requests: Sequence[Request], model: LinearLatencyModel,
             max_batch: int,
             policy: Union[str, SchedulingPolicy] = "fcfs", *,
             num_instances: int = 1,
             discipline: Union[str, ExecutionDiscipline, None] = None,
             noise_sigma: float = 0.0,
             rng: Optional[np.random.Generator] = None,
             respect_arrivals: bool = True,
             inter_batch_gap: float = 0.0,
             sa_params: Optional[SAParams] = None,
             reanneal_min_queue: int = 2) -> SimResult:
    """Run the unified discrete-event serving loop.

    Parameters
    ----------
    policy : a :class:`SchedulingPolicy` (shared across instances), a v1
        ``select``-style object (deprecated, adapted automatically), or a
        registry key — ``"fcfs"`` / ``"priority"`` / ``"slo-reanneal"``
        / ``"slo-preempt"``.
    discipline : an :class:`ExecutionDiscipline` or registry key
        (``"stall"``, ``"chunked"``, ``"chunked:32"``).  Default:
        :class:`StallingPrefill`.
    num_instances : parallel servers draining the shared pending queue.
    respect_arrivals : when False, every request is available at t=0 and
        metrics are absolute (the classic offline-pool convention of the
        ``run_*`` wrappers); when True, arrivals follow
        ``Request.arrival_time`` and metrics are arrival-relative.
    inter_batch_gap : idle gap inserted before each non-first admission
        into a fully drained instance (planned-dispatch convention).
    """
    pol, preemptive = resolve_policy(policy, model=model,
                                     max_batch=max_batch,
                                     sa_params=sa_params,
                                     min_queue=reanneal_min_queue)
    if discipline is None:
        # a policy that carries its own discipline (dynamic-chunk's
        # AdaptiveChunkedPrefill) executes under it — same convention
        # as Engine.run_policy, and object identity is preserved so
        # the policy's per-tick retuning reaches the planner
        discipline = getattr(pol, "discipline", None)
    disc = make_discipline(discipline)
    res = SimResult({}, {}, {}, {})

    def arr_of(r: Request) -> float:
        return r.arrival_time if respect_arrivals else 0.0

    def cp_of(r: Request) -> int:
        """Cached-prefix tokens (shared-prefix KV reuse): that span of
        the prompt is aliased, not computed, so prefill is charged for
        the unique suffix only.  Clipped below the prompt length — at
        least one token is always computed.  Survives preemption: the
        prefix index owns the pages, so a re-prefill skips them again."""
        cp = int(getattr(r, "cached_prefix", 0) or 0)
        return min(max(cp, 0), r.input_len - 1)

    future = sorted(requests, key=arr_of)          # stable for ties
    fi = 0
    pending: List[Request] = []
    # preempted-request carry state: req_id -> {"gen", "ttft"}
    carry: Dict[int, dict] = {}
    insts = [_Instance() for _ in range(num_instances)]

    def finish(a: dict, clock: float):
        r = a["req"]
        base = arr_of(r)
        e2e = clock - base
        ttft = a["ttft"] - base
        tpot = (clock - a["ttft"]) / max(a["gen"], 1)
        res.e2e[r.req_id] = e2e
        res.ttft[r.req_id] = ttft
        res.tpot[r.req_id] = tpot
        res.met[r.req_id] = meets_slo(r, e2e, ttft, tpot)

    def decode_round(inst: _Instance):
        """One decode iteration over the instance's active set."""
        if not inst.active:
            return
        b = len(inst.active)
        step = max(model.per_token_decode_time(b, a["accum"])
                   for a in inst.active) * _noise(rng, noise_sigma)
        inst.clock += step
        still = []
        for a in inst.active:
            a["gen"] += 1
            a["accum"] += 1
            a["remaining"] -= 1
            if a["remaining"] <= 0:
                finish(a, inst.clock)
            else:
                still.append(a)
        inst.active = still

    def activate(inst: _Instance, r: Request, gen0: int,
                 ttft0: Optional[float]):
        """Register a freshly (re-)prefilled request as active."""
        lo = r.output_len if r.output_len is not None \
            else r.planning_output_len()
        gen = gen0 + 1                       # prefill emits the next token
        a = {"req": r, "accum": r.input_len + gen, "gen": gen,
             "remaining": max(int(lo), 1) - gen,
             "ttft": ttft0 if ttft0 is not None else inst.clock}
        if a["remaining"] <= 0:              # that token was the last
            finish(a, inst.clock)
        else:
            inst.active.append(a)

    def stage_prefill(inst: _Instance, admitted: List[Request]):
        """Stage the admitted set: each request joins the instance's
        prefilling list (claiming its capacity); the per-tick step plan
        below advances and eventually activates it.  The compute span
        is the unique suffix only (cached prefix aliased), plus any
        preemption-carried tokens (vLLM-style KV recompute)."""
        for r in admitted:
            st = carry.pop(r.req_id, None)
            gen0 = st["gen"] if st else 0
            inst.prefilling.append({
                "req": r, "done": 0,
                "total": r.input_len - cp_of(r) + gen0,
                "gen0": gen0, "ttft0": st["ttft"] if st else None})

    def run_plan(inst: _Instance):
        """Execute one tick's :class:`StepPlan` — the sim twin of
        ``Engine.execute_step``: advance every planned prefill span,
        activate completed prefills, then one decode round over the
        active set (freshly activated requests ride the same tick)."""
        plan = disc.plan_step(
            [(k, p["done"], p["total"])
             for k, p in enumerate(inst.prefilling)],
            range(len(inst.active)))
        pre = plan.prefills
        if pre:
            if disc.chunk_size <= 0:
                # batched whole-prompt prefill: one tick, priced at the
                # max member time; running decodes stall behind it
                inst.clock += max(
                    model.prefill_time(len(pre), it.length)
                    * _noise(rng, noise_sigma) for it in pre)
            else:
                # chunks execute back-to-back within the tick, exactly
                # as the engine's execute_step runs its prefill items
                inst.clock += sum(
                    model.prefill_time(1, it.length)
                    * _noise(rng, noise_sigma) for it in pre)
            done_items = []
            for it in pre:
                p = inst.prefilling[it.ref]
                p["done"] += it.length
                if it.last:
                    done_items.append(p)
            for p in done_items:
                inst.prefilling.remove(p)
                activate(inst, p["req"], p["gen0"], p["ttft0"])
        decode_round(inst)
        return bool(pre)

    def make_view(inst: _Instance, idx: int,
                  pend: Sequence[Request]) -> SchedulerView:
        b = max(len(inst.active), 1)
        return SchedulerView(
            pending=tuple(pend),
            active=tuple(make_active_view(
                a["req"], a["gen"], a["remaining"], a["accum"],
                inst.clock, a["ttft"], arr_of(a["req"]), b, model)
                for a in inst.active),
            now=inst.clock,
            # slots mid-prefill hold capacity: they are neither free
            # nor active (exactly the engine's PREFILLING accounting)
            free=max_batch - len(inst.active) - len(inst.prefilling),
            max_batch=max_batch, instance_id=idx,
            pending_generated=tuple(
                carry.get(r.req_id, {}).get("gen", 0) for r in pend),
            discipline=disc,
            pending_cached=tuple(cp_of(r) for r in pend))

    while True:
        work_left = pending or fi < len(future)
        runnable = [i for i in insts
                    if i.active or i.prefilling or work_left]
        if not runnable:
            break
        inst = min(runnable, key=lambda i: i.clock)
        idx = insts.index(inst)
        # release arrivals up to this (globally earliest) clock
        while fi < len(future) and arr_of(future[fi]) <= inst.clock:
            r = future[fi]
            r.submit_time = arr_of(r)        # executor clock == sim clock
            pending.append(r)
            fi += 1
        progressed = False
        decided = False
        free = max_batch - len(inst.active) - len(inst.prefilling)
        # scheduling event: the policy sees pending AND active state;
        # consulted with no free slot only if it can preempt
        if pending and (free > 0 or (preemptive and inst.active)):
            view = make_view(inst, idx, pending)
            admit, preempt = normalize_decision(pol.decide(view), view)
            decided = True
            # preemption: evict, discard KV, requeue (indices into
            # view.pending stay valid — preempted go to the tail)
            for j in preempt:
                a = inst.active.pop(j)
                rid = a["req"].req_id
                carry[rid] = {"gen": a["gen"], "ttft": a["ttft"]}
                res.preemptions[rid] = res.preemptions.get(rid, 0) + 1
                pending.append(a["req"])
                progressed = True
            free = max_batch - len(inst.active) - len(inst.prefilling)
            sel = admit[:free]
            if sel:
                admitted = [pending[j] for j in sel]
                for j in sorted(sel, reverse=True):
                    pending.pop(j)
                if inter_batch_gap and inst.dispatched \
                        and not inst.active and not inst.prefilling:
                    inst.clock += inter_batch_gap
                stage_prefill(inst, admitted)
                inst.dispatched = True
                progressed = True
        retune = getattr(pol, "retune", None)
        if not decided and retune is not None \
                and (inst.active or inst.prefilling):
            # decide() didn't run this tick (empty queue): let an
            # adaptive policy keep resizing its chunk against the
            # current active set, as the engine loop does
            retune(make_view(inst, idx, ()))
        # one plan tick: prefill spans + a decode round (chunk-as-tick)
        if inst.active or inst.prefilling:
            run_plan(inst)
            progressed = True
        if not progressed:
            if fi < len(future):                  # idle until next arrival
                inst.clock = max(inst.clock, arr_of(future[fi]))
            else:
                raise RuntimeError(
                    "admission stalled: the policy admitted nothing while "
                    "an idle instance had pending requests")
    return res
