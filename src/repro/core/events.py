"""Unified discrete-event serving core.

Historically this repo carried *three* hand-rolled continuous-batching
loops — ``online.simulate_online``, ``simulator.run_fcfs_continuous`` (and
friends), and the slot pool inside ``engine.Engine`` — whose token
accounting had silently diverged: the real engine samples the first token
from the prefill logits (TTFT *is* the first generated token, so a request
needs ``l_o - 1`` decode rounds), while both simulators required ``l_o``
decode rounds after TTFT and computed TPOT over a different token count.

This module is now the single execution loop.  ``simulate`` is a
token-granularity discrete-event simulator with

  * pluggable admission policies (:class:`FCFSPolicy`,
    :class:`PlannedPolicy`, :class:`SLOReannealPolicy`) — the *same*
    policy objects also drive the real engine's admission
    (``Engine.run_policy``), so simulated and measured runs share one
    scheduling brain;
  * multi-instance support: ``num_instances`` servers draining a shared
    pending queue (instances advance asynchronously; the earliest-clock
    instance always acts first, so arrival causality is preserved);
  * arrivals over time (``respect_arrivals=True``) or a classic offline
    pool (all requests available at t=0).

Execution semantics (engine-faithful — the fix for the historical drift):

  * prefill of an admitted set is batched: it completes at
    ``clock + max(member prefill times)``; that instant is TTFT *and* the
    first generated token (``gen = 1``, context length ``l_i + 1``);
  * each decode round generates one token for every active request and
    costs the max per-token decode time over the active set; a request
    finishes once ``gen == l_o`` — i.e. ``l_o - 1`` decode rounds after
    prefill (a request with ``l_o == 1`` finishes at prefill);
  * TPOT = (e2e − TTFT) / l_o, matching ``RuntimeRequest.metrics``;
  * prefills stall the instance's running decodes (non-chunked), and the
    prefill batch size is the admitted-set size (simulator convention —
    the engine prefills slot-by-slot; see ``engine.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.annealing import SAParams, priority_mapping
from repro.core.latency_model import LinearLatencyModel
from repro.core.slo import Request, as_arrays, meets_slo


@dataclasses.dataclass
class SimResult:
    e2e: Dict[int, float]
    ttft: Dict[int, float]
    tpot: Dict[int, float]
    met: Dict[int, bool]

    @property
    def n(self):
        return len(self.e2e)

    @property
    def attainment(self) -> float:
        return sum(self.met.values()) / max(self.n, 1)

    @property
    def total_latency(self) -> float:
        return sum(self.e2e.values())

    @property
    def avg_latency(self) -> float:
        return self.total_latency / max(self.n, 1)

    @property
    def G(self) -> float:
        t = self.total_latency
        return sum(self.met.values()) / t if t > 0 else 0.0

    def merged_with(self, other: "SimResult") -> "SimResult":
        return SimResult(e2e={**self.e2e, **other.e2e},
                         ttft={**self.ttft, **other.ttft},
                         tpot={**self.tpot, **other.tpot},
                         met={**self.met, **other.met})


def _noise(rng: Optional[np.random.Generator], sigma: float) -> float:
    if rng is None or sigma <= 0:
        return 1.0
    return float(np.exp(rng.normal(0.0, sigma)))


def _with_remaining_slo(r: Request, now: float) -> Request:
    """Shift e2e/TTFT budgets by the time already waited."""
    waited = max(0.0, now - r.arrival_time)
    slo = r.slo
    new = dataclasses.replace(
        slo,
        e2e=(slo.e2e - waited) if slo.e2e is not None else None,
        ttft=(slo.ttft - waited) if slo.ttft is not None else None)
    return dataclasses.replace(r, slo=new)


# --------------------------------------------------------------- policies
class AdmissionPolicy:
    """Decides which pending requests an instance admits next.

    ``select`` returns indices into ``pending`` in admission order; the
    caller truncates to the available slots.  The same objects drive both
    the discrete-event core (`simulate`) and the real serving engine
    (``Engine.run_policy``).
    """

    def select(self, pending: Sequence[Request], now: float, free: int,
               active_count: int) -> List[int]:
        raise NotImplementedError


class FCFSPolicy(AdmissionPolicy):
    """vLLM-like continuous batching: admit in arrival (list) order.

    Also serves the planned-*priority* path: the scheduler's priority
    order is applied upstream by flattening the planned batches."""

    def select(self, pending, now, free, active_count):
        return list(range(min(free, len(pending))))


class PlannedPolicy(AdmissionPolicy):
    """Execute planned batches sequentially with a barrier (the paper's
    dispatch discipline): the next batch is admitted only once the
    instance drained completely."""

    def __init__(self, batches: Sequence[Sequence]):
        self._batches = [[getattr(r, "req_id", r) for r in b]
                         for b in batches if len(b)]
        self._next = 0

    def select(self, pending, now, free, active_count):
        if active_count > 0 or self._next >= len(self._batches):
            return []
        batch = self._batches[self._next]
        pos = {r.req_id: i for i, r in enumerate(pending)}
        if any(rid not in pos for rid in batch):
            return []                       # members not yet arrived
        if len(batch) > free:
            raise RuntimeError("slot pool smaller than planned batch")
        self._next += 1
        return [pos[rid] for rid in batch]


class SLOReannealPolicy(AdmissionPolicy):
    """Re-anneal the waiting queue with Algorithm 1 at every admission
    event, with SLO budgets shrunk by the time each request already
    waited.  The incremental-Δ annealer keeps this cheap enough to run on
    the admission hot path (paper Table 1)."""

    def __init__(self, model: LinearLatencyModel, max_batch: int,
                 sa_params: Optional[SAParams] = None, min_queue: int = 2):
        self.model = model
        self.max_batch = max_batch
        self.sa_params = sa_params if sa_params is not None \
            else SAParams(seed=0)
        self.min_queue = min_queue

    def select(self, pending, now, free, active_count):
        if len(pending) < self.min_queue:
            return list(range(min(free, len(pending))))
        shifted = [_with_remaining_slo(r, now) for r in pending]
        sa = priority_mapping(as_arrays(shifted), self.model,
                              self.max_batch, self.sa_params)
        return [int(i) for i in sa.perm]


_POLICY_STRINGS = ("fcfs", "priority", "slo-reanneal")


def _make_policy(policy, model, max_batch, sa_params, reanneal_min_queue
                 ) -> AdmissionPolicy:
    if isinstance(policy, AdmissionPolicy):
        return policy
    if policy in ("fcfs", "priority"):
        return FCFSPolicy()
    if policy == "slo-reanneal":
        return SLOReannealPolicy(model, max_batch, sa_params,
                                 reanneal_min_queue)
    raise ValueError(f"unknown policy {policy!r}; expected an "
                     f"AdmissionPolicy or one of {_POLICY_STRINGS}")


# ------------------------------------------------------------------- core
class _Instance:
    __slots__ = ("clock", "active", "dispatched")

    def __init__(self, clock: float = 0.0):
        self.clock = clock
        self.active: List[dict] = []
        self.dispatched = False


def simulate(requests: Sequence[Request], model: LinearLatencyModel,
             max_batch: int,
             policy: Union[str, AdmissionPolicy] = "fcfs", *,
             num_instances: int = 1,
             noise_sigma: float = 0.0,
             rng: Optional[np.random.Generator] = None,
             respect_arrivals: bool = True,
             inter_batch_gap: float = 0.0,
             sa_params: Optional[SAParams] = None,
             reanneal_min_queue: int = 2) -> SimResult:
    """Run the unified discrete-event serving loop.

    Parameters
    ----------
    policy : an :class:`AdmissionPolicy` (shared across instances) or one
        of ``"fcfs"`` / ``"priority"`` / ``"slo-reanneal"``.
    num_instances : parallel servers draining the shared pending queue.
    respect_arrivals : when False, every request is available at t=0 and
        metrics are absolute (the classic offline-pool convention of the
        ``run_*`` wrappers); when True, arrivals follow
        ``Request.arrival_time`` and metrics are arrival-relative.
    inter_batch_gap : idle gap inserted before each non-first admission
        into a fully drained instance (planned-dispatch convention).
    """
    pol = _make_policy(policy, model, max_batch, sa_params,
                       reanneal_min_queue)
    res = SimResult({}, {}, {}, {})

    def arr_of(r: Request) -> float:
        return r.arrival_time if respect_arrivals else 0.0

    future = sorted(requests, key=arr_of)          # stable for ties
    fi = 0
    pending: List[Request] = []
    insts = [_Instance() for _ in range(num_instances)]

    def finish(a: dict, clock: float):
        r = a["req"]
        base = arr_of(r)
        e2e = clock - base
        ttft = a["ttft"] - base
        tpot = (clock - a["ttft"]) / max(a["gen"], 1)
        res.e2e[r.req_id] = e2e
        res.ttft[r.req_id] = ttft
        res.tpot[r.req_id] = tpot
        res.met[r.req_id] = meets_slo(r, e2e, ttft, tpot)

    while True:
        work_left = pending or fi < len(future)
        runnable = [i for i in insts if i.active or work_left]
        if not runnable:
            break
        inst = min(runnable, key=lambda i: i.clock)
        # release arrivals up to this (globally earliest) clock
        while fi < len(future) and arr_of(future[fi]) <= inst.clock:
            pending.append(future[fi])
            fi += 1
        progressed = False
        # admission: fill free slots; prefill stalls the running batch
        free = max_batch - len(inst.active)
        if free > 0 and pending:
            sel = list(pol.select(pending, inst.clock, free,
                                  len(inst.active)))[:free]
            if sel:
                admitted = [pending[j] for j in sel]
                for j in sorted(sel, reverse=True):
                    pending.pop(j)
                if inter_batch_gap and inst.dispatched and not inst.active:
                    inst.clock += inter_batch_gap
                b = len(admitted)
                inst.clock += max(
                    model.prefill_time(b, r.input_len)
                    * _noise(rng, noise_sigma) for r in admitted)
                inst.dispatched = True
                for r in admitted:
                    lo = r.output_len if r.output_len is not None \
                        else r.planning_output_len()
                    a = {"req": r, "accum": r.input_len + 1, "gen": 1,
                         "remaining": max(int(lo), 1) - 1,
                         "ttft": inst.clock}
                    if a["remaining"] <= 0:       # first token was the last
                        finish(a, inst.clock)
                    else:
                        inst.active.append(a)
                progressed = True
        # one decode round over the active set
        if inst.active:
            b = len(inst.active)
            step = max(model.per_token_decode_time(b, a["accum"])
                       for a in inst.active) * _noise(rng, noise_sigma)
            inst.clock += step
            still = []
            for a in inst.active:
                a["gen"] += 1
                a["accum"] += 1
                a["remaining"] -= 1
                if a["remaining"] <= 0:
                    finish(a, inst.clock)
                else:
                    still.append(a)
            inst.active = still
            progressed = True
        if not progressed:
            if fi < len(future):                  # idle until next arrival
                inst.clock = max(inst.clock, arr_of(future[fi]))
            else:
                raise RuntimeError(
                    "admission stalled: the policy admitted nothing while "
                    "an idle instance had pending requests")
    return res
