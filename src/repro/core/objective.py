"""Schedule objective G (paper §3.1, Eqs. 1–13).

A *schedule* for N requests is
  * ``perm``      — permutation of request indices (priority order), and
  * ``batch_id``  — monotone non-decreasing batch index per *position*
                    (positions are contiguous within a batch).

Execution semantics (paper Eq. 10–12): batches run sequentially; every
request in batch j starts once batches 0..j-1 finished; batch j's duration
is the max exec time of its members, each evaluated at batch size b_j.

``evaluate`` is fully vectorized (numpy) — O(N) per schedule — and is the
single source of truth used by both the Python and the JAX annealers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.latency_model import LinearLatencyModel


@dataclasses.dataclass
class ScheduleEval:
    G: float
    n_met: int
    total_latency: float          # t = Σ t_e2e  (Eq. 3)
    avg_latency: float
    attainment: float
    e2e: np.ndarray               # per original request index
    ttft: np.ndarray
    tpot: np.ndarray
    met: np.ndarray


def batch_sizes_from_id(batch_id: np.ndarray) -> np.ndarray:
    m = int(batch_id[-1]) + 1 if len(batch_id) else 0
    return np.bincount(batch_id, minlength=m)


def evaluate(arrays: dict, model: LinearLatencyModel, perm: np.ndarray,
             batch_id: np.ndarray) -> ScheduleEval:
    """arrays: columnar request view (slo.as_arrays)."""
    li = arrays["input_len"][perm]
    lo = arrays["output_len"][perm]
    h = arrays["h"][perm]
    slo_e2e = arrays["slo_e2e"][perm]
    slo_ttft = arrays["slo_ttft"][perm]
    slo_tpot = arrays["slo_tpot"][perm]

    n = len(perm)
    nb = int(batch_id[-1]) + 1 if n else 0
    bsz = np.bincount(batch_id, minlength=nb).astype(np.float64)
    b_of = bsz[batch_id]                                  # batch size per pos

    t_exec = model.exec_time(b_of, li, lo)                # Eq. 17
    t_pref = model.prefill_time(b_of, li)                 # Eq. 18
    t_tpot = model.tpot(b_of, li, lo)                     # Eq. 19

    # batch duration = max member exec; wait = cumsum of previous batches
    bdur = np.zeros(nb)
    np.maximum.at(bdur, batch_id, t_exec)
    wait_of_batch = np.concatenate([[0.0], np.cumsum(bdur)[:-1]])
    t_wait = wait_of_batch[batch_id]                      # Eq. 11

    e2e = t_exec + t_wait                                 # Eq. 4
    ttft = t_pref + t_wait                                # Eq. 8

    met = np.where(h == 1,
                   e2e <= slo_e2e,
                   (ttft <= slo_ttft) & (t_tpot <= slo_tpot))  # Eq. 7
    n_met = int(met.sum())
    total = float(e2e.sum())
    G = n_met / total if total > 0 else 0.0               # Eq. 2

    # scatter back to original request order
    inv = np.empty(n, np.int64)
    inv[perm] = np.arange(n)
    return ScheduleEval(
        G=G, n_met=n_met, total_latency=total,
        avg_latency=total / max(n, 1),
        attainment=n_met / max(n, 1),
        e2e=e2e[inv], ttft=ttft[inv], tpot=t_tpot[inv], met=met[inv],
    )


def calculate_g(arrays, model, perm, batch_id) -> float:
    return evaluate(arrays, model, np.asarray(perm), np.asarray(batch_id)).G


def fcfs_schedule(n: int, max_batch: int):
    """Arrival order, maximal batches — the paper's 'initial sequence'."""
    perm = np.arange(n)
    batch_id = np.arange(n) // max_batch
    return perm, batch_id


def sorted_by_e2e_schedule(arrays, model, max_batch: int):
    """Priority aligned with predicted e2e latency (Algorithm 1 line 3)."""
    li, lo = arrays["input_len"], arrays["output_len"]
    t = model.exec_time(np.minimum(max_batch, len(li)), li, lo)
    perm = np.argsort(t, kind="stable")
    batch_id = np.arange(len(li)) // max_batch
    return perm, batch_id
