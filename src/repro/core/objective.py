"""Schedule objective G (paper §3.1, Eqs. 1–13).

A *schedule* for N requests is
  * ``perm``      — permutation of request indices (priority order), and
  * ``batch_id``  — monotone non-decreasing batch index per *position*
                    (positions are contiguous within a batch).

Execution semantics (paper Eq. 10–12): batches run sequentially; every
request in batch j starts once batches 0..j-1 finished; batch j's duration
is the max exec time of its members, each evaluated at batch size b_j.

``evaluate`` is fully vectorized (numpy) — O(N) per schedule — and is the
oracle both annealers are validated against.  The Python annealer's hot
loop no longer calls it per proposal: :class:`IncrementalEvaluator` keeps
per-batch aggregates and scores a move in O(touched batch + n_batches).
"""
from __future__ import annotations

import dataclasses
from bisect import bisect_left
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.latency_model import LinearLatencyModel


@dataclasses.dataclass
class ScheduleEval:
    G: float
    n_met: int
    total_latency: float          # t = Σ t_e2e  (Eq. 3)
    avg_latency: float
    attainment: float
    e2e: np.ndarray               # per original request index
    ttft: np.ndarray
    tpot: np.ndarray
    met: np.ndarray


def batch_sizes_from_id(batch_id: np.ndarray) -> np.ndarray:
    m = int(batch_id[-1]) + 1 if len(batch_id) else 0
    return np.bincount(batch_id, minlength=m)


def evaluate(arrays: dict, model: LinearLatencyModel, perm: np.ndarray,
             batch_id: np.ndarray) -> ScheduleEval:
    """arrays: columnar request view (slo.as_arrays)."""
    li = arrays["input_len"][perm]
    lo = arrays["output_len"][perm]
    h = arrays["h"][perm]
    slo_e2e = arrays["slo_e2e"][perm]
    slo_ttft = arrays["slo_ttft"][perm]
    slo_tpot = arrays["slo_tpot"][perm]
    cp = _cached_col(arrays)
    cp = cp[perm] if cp is not None else 0.0

    n = len(perm)
    nb = int(batch_id[-1]) + 1 if n else 0
    bsz = np.bincount(batch_id, minlength=nb).astype(np.float64)
    b_of = bsz[batch_id]                                  # batch size per pos

    # shared-prefix reuse: prefill is priced at the unique new tokens
    # (l_i - cached_prefix); decode keeps the full context l_i
    t_exec = model.exec_time(b_of, li, lo, cached=cp)     # Eq. 17
    t_pref = model.prefill_time(b_of, li, cached=cp)      # Eq. 18
    t_tpot = model.tpot(b_of, li, lo)                     # Eq. 19

    # batch duration = max member exec; wait = cumsum of previous batches
    bdur = np.zeros(nb)
    np.maximum.at(bdur, batch_id, t_exec)
    wait_of_batch = np.concatenate([[0.0], np.cumsum(bdur)[:-1]])
    t_wait = wait_of_batch[batch_id]                      # Eq. 11

    e2e = t_exec + t_wait                                 # Eq. 4
    ttft = t_pref + t_wait                                # Eq. 8

    met = np.where(h == 1,
                   e2e <= slo_e2e,
                   (ttft <= slo_ttft) & (t_tpot <= slo_tpot))  # Eq. 7
    n_met = int(met.sum())
    total = float(e2e.sum())
    G = n_met / total if total > 0 else 0.0               # Eq. 2

    # scatter back to original request order
    inv = np.empty(n, np.int64)
    inv[perm] = np.arange(n)
    return ScheduleEval(
        G=G, n_met=n_met, total_latency=total,
        avg_latency=total / max(n, 1),
        attainment=n_met / max(n, 1),
        e2e=e2e[inv], ttft=ttft[inv], tpot=t_tpot[inv], met=met[inv],
    )


def calculate_g(arrays, model, perm, batch_id) -> float:
    return evaluate(arrays, model, np.asarray(perm), np.asarray(batch_id)).G


def fcfs_schedule(n: int, max_batch: int):
    """Arrival order, maximal batches — the paper's 'initial sequence'."""
    perm = np.arange(n)
    batch_id = np.arange(n) // max_batch
    return perm, batch_id


def sorted_by_e2e_schedule(arrays, model, max_batch: int):
    """Priority aligned with predicted e2e latency (Algorithm 1 line 3)."""
    li, lo = arrays["input_len"], arrays["output_len"]
    t = model.exec_time(np.minimum(max_batch, len(li)), li, lo)
    perm = np.argsort(t, kind="stable")
    batch_id = np.arange(len(li)) // max_batch
    return perm, batch_id


def _cached_col(arrays: dict):
    """Per-request cached-prefix column (``slo.as_arrays``), clipped to
    [0, l_i - 1]; None when the workload carries no prefix metadata."""
    cp = arrays.get("cached_prefix")
    if cp is None:
        return None
    li = np.asarray(arrays["input_len"], np.float64)
    return np.clip(np.asarray(cp, np.float64), 0.0, np.maximum(li - 1, 0.0))


# ------------------------------------------------------------ incremental
def linear_request_coefs(arrays: dict, model) -> dict:
    """Per-request coefficients of the latency model, linear in batch size.

    ``LinearLatencyModel`` (Eqs. 14-16) is linear in the batch size ``b``,
    so every per-request quantity a schedule evaluator needs collapses to
    a pair ``A·b + C`` precomputed once per request:

      exec_time(b)    = eA·b + eC        (Eq. 17)
      prefill_time(b) = pA·b + pC        (Eq. 18)
      tpot(b)         = tA·b + tC        (Eq. 19, output length clamped
                                          to >= 1 exactly as model.tpot)

    This is the *shared contract* between the two incremental annealer
    backends: :class:`IncrementalEvaluator` (Python hot loop) and the
    jitted annealer (:mod:`repro.core.annealing_jax`) both build their
    per-batch slack segments from these arrays, and both are cross-checked
    against the full :func:`evaluate` oracle (see docs/annealer.md).

    Returns a dict of float64 arrays: eA, eC, pA, pC, tA, tC.
    """
    li = np.asarray(arrays["input_len"], np.float64)
    lo = np.asarray(arrays["output_len"], np.float64)
    lo_c = np.maximum(lo, 1.0)
    # shared-prefix reuse: prefill coefficients are built from the
    # *unique* prompt span l_i - cached_prefix (exec = that prefill plus
    # the full-context decode; TPOT is decode-only, so untouched) — this
    # single discount is what makes BOTH annealer backends rank
    # cached-prefix requests by their true (shorter) prefill
    cp = _cached_col(arrays)
    lp = li - cp if cp is not None else li
    tri = li * lo + lo * (lo + 1) / 2.0              # Eq. 16 closed form
    # model.tpot clamps l_o to 1 *before* recomputing the decode time,
    # so the TPOT coefficients must be built from the clamped length
    tri_c = li * lo_c + lo_c * (lo_c + 1) / 2.0
    m = model
    return {
        "eA": m.alpha_p * lp + m.beta_p + m.alpha_d * tri + m.beta_d * lo,
        "eC": m.gamma_p * lp + m.delta_p + m.gamma_d * tri + m.delta_d * lo,
        "pA": m.alpha_p * lp + m.beta_p,
        "pC": m.gamma_p * lp + m.delta_p,
        "tA": (m.alpha_d * tri_c + m.beta_d * lo_c) / lo_c,
        "tC": (m.gamma_d * tri_c + m.delta_d * lo_c) / lo_c,
    }


class _BatchStat:
    """Aggregates for one batch at its current size."""
    __slots__ = ("size", "sum_exec", "bdur", "slacks")

    def __init__(self, size: int, sum_exec: float, bdur: float,
                 slacks: List[float]):
        self.size = size
        self.sum_exec = sum_exec
        self.bdur = bdur                 # batch duration = max member exec
        self.slacks = slacks             # sorted wait thresholds (see below)


class IncrementalEvaluator:
    """Incremental ΔG evaluation for Algorithm 1's move set.

    The key observation: given a batch's size, every member contributes a
    *wait threshold* ("slack") — the largest batch wait under which it
    still meets its SLO:

      h = 1:  met  ⇔  wait ≤ slo_e2e − t_exec
      h = 0:  met  ⇔  wait ≤ slo_ttft − t_prefill   (and TPOT ok,
                                                     wait-independent)

    so ``n_met`` of a batch with wait w is a binary search over its sorted
    slacks, and Σe2e of a batch is ``sum_exec + size·w``.  A squeeze /
    delay / swap move perturbs one or two batches; downstream batches keep
    their member stats and only see a shifted wait.  Scoring a proposal is
    therefore O(touched-batch rebuild + n_batches·log b) instead of the
    O(N) full :func:`evaluate` — cheap enough to re-anneal at every
    admission event (paper Table 1).

    Relies on the latency model being *linear in batch size b* (true of
    ``LinearLatencyModel``, Eqs. 14–16): every per-request quantity is
    precomputed once as ``A·b + C``.

    ``evaluate`` remains the oracle; tests cross-check agreement to 1e-9.
    """

    def __init__(self, arrays: dict, model, batches: Sequence[Sequence[int]]):
        # exec_time(b) = eA·b + eC ; prefill(b) = pA·b + pC ; tpot(b) = tA·b+tC
        coefs = linear_request_coefs(arrays, model)
        self._eA = coefs["eA"].tolist()
        self._eC = coefs["eC"].tolist()
        self._pA = coefs["pA"].tolist()
        self._pC = coefs["pC"].tolist()
        self._tA = coefs["tA"].tolist()
        self._tC = coefs["tC"].tolist()
        self._h = [int(x) for x in arrays["h"]]
        self._se = [float(x) for x in arrays["slo_e2e"]]
        self._st = [float(x) for x in arrays["slo_ttft"]]
        self._sp = [float(x) for x in arrays["slo_tpot"]]
        self.batches: List[List[int]] = [list(b) for b in batches if len(b)]
        self.stats: List[_BatchStat] = [self._stat(b) for b in self.batches]
        self._recache()

    # ------------------------------------------------------------ internals
    def _stat(self, members: Sequence[int]) -> _BatchStat:
        b = float(len(members))
        eA, eC, h = self._eA, self._eC, self._h
        sum_exec = 0.0
        bdur = float("-inf")
        slacks = []
        for i in members:
            ex = eA[i] * b + eC[i]
            sum_exec += ex
            if ex > bdur:
                bdur = ex
            if h[i]:
                s = self._se[i] - ex
            elif self._tA[i] * b + self._tC[i] <= self._sp[i]:
                s = self._st[i] - (self._pA[i] * b + self._pC[i])
            else:
                s = float("-inf")
            slacks.append(s)
        slacks.sort()
        return _BatchStat(len(members), sum_exec, bdur, slacks)

    def _recache(self, k0: int = 0):
        """Prefix aggregates of the committed schedule: cum_met[j] /
        cum_total[j] over batches < j, and wait[j] of batch j.  Batches
        below ``k0`` are unchanged, so their prefixes are reused."""
        k0 = min(k0, len(self.stats))
        cm = self._cum_met[:k0 + 1] if k0 else [0]
        ct = self._cum_total[:k0 + 1] if k0 else [0.0]
        cw = self._cum_wait[:k0 + 1] if k0 else [0.0]
        n_met, total, w = cm[-1], ct[-1], cw[-1]
        # NOTE: this accumulation body must stay in sync with _aggregate
        # (kept as two tight loops on purpose — _aggregate is the anneal's
        # per-proposal hot path and the 1e-9 oracle-agreement tests pin
        # both against evaluate())
        for st in self.stats[k0:]:
            sz = st.size
            if sz == 1:                      # common at small max_batch
                n_met += st.slacks[0] >= w
                total += st.sum_exec + w
            else:
                n_met += sz - bisect_left(st.slacks, w)
                total += st.sum_exec + sz * w
            w += st.bdur
            cm.append(n_met)
            ct.append(total)
            cw.append(w)
        self._cum_met, self._cum_total, self._cum_wait = cm, ct, cw
        self.n_met = n_met
        self.total = total
        self.G = n_met / total if total > 0 else 0.0

    def _aggregate(self, stats: List[_BatchStat], k0: int
                   ) -> Tuple[float, int]:
        """Score a candidate whose batches < k0 are unchanged."""
        n_met = self._cum_met[k0]
        total = self._cum_total[k0]
        w = self._cum_wait[k0]
        # NOTE: keep in sync with _recache's accumulation body (see there)
        for st in stats[k0:]:
            sz = st.size
            if sz == 1:                      # common at small max_batch
                n_met += st.slacks[0] >= w
                total += st.sum_exec + w
            else:
                n_met += sz - bisect_left(st.slacks, w)
                total += st.sum_exec + sz * w
            w += st.bdur
        return (n_met / total if total > 0 else 0.0), n_met

    # ------------------------------------------------------------ moves
    def preview(self, move) -> Tuple[float, int, tuple]:
        """Score ``move`` (an annealing move descriptor) without mutating
        state.  Returns ``(G, n_met, staged)``; pass ``staged`` to
        :meth:`commit` to adopt the candidate.  Inner batch lists are
        never mutated in place, so committed ``batches`` may be aliased by
        callers safely."""
        batches = list(self.batches)
        stats = list(self.stats)
        op = move[0]
        if op == "squeeze":                    # batch k -> k-1
            k, j = move[1], move[2]
            src = batches[k]
            item = src[j]
            rem = src[:j] + src[j + 1:]
            dst = batches[k - 1] + [item]
            batches[k - 1] = dst
            stats[k - 1] = self._stat(dst)
            if rem:
                batches[k] = rem
                stats[k] = self._stat(rem)
            else:
                del batches[k]
                del stats[k]
            k0 = k - 1
        elif op == "delay":                    # batch k -> k+1 (maybe new)
            k, j = move[1], move[2]
            src = batches[k]
            item = src[j]
            rem = src[:j] + src[j + 1:]
            if k == len(batches) - 1:          # open a new final iteration
                if rem:
                    batches[k] = rem
                    stats[k] = self._stat(rem)
                    batches.append([item])
                    stats.append(self._stat([item]))
                else:
                    # delaying a singleton last batch is structurally a
                    # no-op; never keep an empty batch (bdur would be -inf)
                    batches[k] = [item]
                    stats[k] = self._stat([item])
            else:
                dst = [item] + batches[k + 1]
                batches[k + 1] = dst
                stats[k + 1] = self._stat(dst)
                if rem:
                    batches[k] = rem
                    stats[k] = self._stat(rem)
                else:
                    del batches[k]
                    del stats[k]
            k0 = k
        elif op == "swap":
            b1, i1, b2, i2 = move[1], move[2], move[3], move[4]
            if b1 == b2:                       # same batch: G is invariant
                nl = list(batches[b1])
                nl[i1], nl[i2] = nl[i2], nl[i1]
                batches[b1] = nl
                k0 = len(stats)                # reuse full committed prefix
            else:
                l1, l2 = list(batches[b1]), list(batches[b2])
                l1[i1], l2[i2] = l2[i2], l1[i1]
                batches[b1], batches[b2] = l1, l2
                stats[b1] = self._stat(l1)
                stats[b2] = self._stat(l2)
                k0 = min(b1, b2)
        else:
            raise ValueError(f"unknown move {move!r}")
        g, n_met = self._aggregate(stats, k0)
        return g, n_met, (batches, stats, k0)

    def commit(self, staged: tuple):
        self.batches, self.stats, k0 = staged
        self._recache(k0)
