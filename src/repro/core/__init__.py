"""The paper's primary contribution: SLO-aware scheduling for LLM inference.

Components: latency predictor (Eqs. 14-19), request profiler, simulated-
annealing priority mapper (Algorithm 1), multi-instance scheduler
(Algorithm 2), objective G (Eq. 2), exhaustive-search oracle, and the
discrete-event execution simulator used by the benchmarks.
"""
from repro.core.slo import SLO, Request, as_arrays, meets_slo
from repro.core.latency_model import LinearLatencyModel, PAPER_TABLE2, fit
from repro.core.objective import (IncrementalEvaluator, ScheduleEval,
                                  calculate_g, evaluate, fcfs_schedule,
                                  sorted_by_e2e_schedule)
from repro.core.annealing import (SAParams, SAResult, apply_move,
                                  priority_mapping, propose_move)
from repro.core.exhaustive import exhaustive_search
from repro.core.profiler import (LatencyProfiler, MemoryModel,
                                 OutputLengthPredictor)
from repro.core.scheduler import (InstanceQueue, ScheduleOutcome,
                                  SLOAwareScheduler)
from repro.core.events import (AdmissionPolicy, FCFSPolicy, PlannedPolicy,
                               SimResult, SLOReannealPolicy, simulate)
from repro.core.simulator import (run_fcfs_continuous, run_multi_instance,
                                  run_planned, run_priority_continuous)
from repro.core.online import simulate_online

__all__ = [
    "SLO", "Request", "as_arrays", "meets_slo",
    "LinearLatencyModel", "PAPER_TABLE2", "fit",
    "ScheduleEval", "calculate_g", "evaluate", "fcfs_schedule",
    "sorted_by_e2e_schedule", "IncrementalEvaluator",
    "SAParams", "SAResult", "priority_mapping", "propose_move", "apply_move",
    "exhaustive_search",
    "LatencyProfiler", "MemoryModel", "OutputLengthPredictor",
    "InstanceQueue", "ScheduleOutcome", "SLOAwareScheduler",
    "AdmissionPolicy", "FCFSPolicy", "PlannedPolicy", "SLOReannealPolicy",
    "simulate", "simulate_online",
    "SimResult", "run_fcfs_continuous", "run_multi_instance", "run_planned",
    "run_priority_continuous",
]
