"""The paper's primary contribution: SLO-aware scheduling for LLM inference.

Components: latency predictor (Eqs. 14-19), request profiler, simulated-
annealing priority mapper (Algorithm 1) with two backends — the Python
incremental-Δ annealer (:mod:`repro.core.annealing`) and the jitted
batched annealer (:mod:`repro.core.annealing_jax`, vmapped over tempering
chains and instances; imported lazily so the core stays importable
without touching the JAX runtime) — multi-instance scheduler
(Algorithm 2), objective G (Eq. 2), exhaustive-search oracle, and the
discrete-event execution simulator used by the benchmarks.  See
docs/ARCHITECTURE.md for the layer map and docs/annealer.md for the
annealer internals.

Scheduling API v2 (:mod:`repro.core.policies`): runtime scheduling is
expressed as two composable abstractions shared verbatim by the
discrete-event core (:func:`repro.core.events.simulate`) and the real
serving engine (``Engine.run_policy``):

  * :class:`SchedulingPolicy` — ``decide(view) -> Decision``: sees the
    pending queue *and* the active set (generated/remaining/slack under
    the latency model) and may both admit and **preempt**.  Built-ins:
    :class:`FCFSPolicy`, :class:`PlannedPolicy`,
    :class:`SLOReannealPolicy`, :class:`SLOPreemptPolicy`.
  * :class:`ExecutionDiscipline` — :class:`StallingPrefill` vs
    :class:`ChunkedPrefill` — how admitted prefills interleave with
    running decode rounds.

Both are constructible from string keys via :func:`repro.core.policies.make`
(e.g. ``make("slo-preempt", model=m)``, ``make("chunked:64")``).

Deprecation path: the v1 ``AdmissionPolicy`` (admit-only
``select(pending, now, free, active_count)``) remains importable for one
release; subclasses and duck-typed ``select`` objects are adapted into
the v2 protocol automatically, with a ``DeprecationWarning``.
"""
from repro.core.slo import SLO, Request, as_arrays, meets_slo
from repro.core.latency_model import LinearLatencyModel, PAPER_TABLE2, fit
from repro.core.objective import (IncrementalEvaluator, ScheduleEval,
                                  calculate_g, evaluate, fcfs_schedule,
                                  sorted_by_e2e_schedule)
from repro.core.annealing import (SAParams, SAResult, apply_move,
                                  priority_mapping, propose_move)
from repro.core.exhaustive import exhaustive_search
from repro.core.profiler import (LatencyProfiler, MemoryModel,
                                 OutputLengthPredictor)
from repro.core.policies import (ActiveView, AdaptiveChunkedPrefill,
                                 AdmissionPolicy, ChunkedPrefill, Decision,
                                 DynamicChunkPolicy, ExecutionDiscipline,
                                 FCFSPolicy, IndexPolicy, PlanItem,
                                 PlannedPolicy, SchedulerView,
                                 SchedulingPolicy, SLOPreemptPolicy,
                                 SLOReannealPolicy, StallingPrefill,
                                 StepPlan, as_scheduling_policy,
                                 make, make_discipline)
from repro.core.scheduler import (InstanceQueue, ScheduleOutcome,
                                  SLOAwareScheduler)
from repro.core.events import SimResult, simulate
from repro.core.simulator import (run_fcfs_continuous, run_multi_instance,
                                  run_planned, run_priority_continuous)
from repro.core.online import simulate_online

__all__ = [
    "SLO", "Request", "as_arrays", "meets_slo",
    "LinearLatencyModel", "PAPER_TABLE2", "fit",
    "ScheduleEval", "calculate_g", "evaluate", "fcfs_schedule",
    "sorted_by_e2e_schedule", "IncrementalEvaluator",
    "SAParams", "SAResult", "priority_mapping", "propose_move", "apply_move",
    "exhaustive_search",
    "LatencyProfiler", "MemoryModel", "OutputLengthPredictor",
    "InstanceQueue", "ScheduleOutcome", "SLOAwareScheduler",
    # scheduling API v2
    "SchedulingPolicy", "SchedulerView", "ActiveView", "Decision",
    "FCFSPolicy", "PlannedPolicy", "SLOReannealPolicy", "SLOPreemptPolicy",
    "IndexPolicy", "DynamicChunkPolicy",
    "ExecutionDiscipline", "StallingPrefill", "ChunkedPrefill",
    "AdaptiveChunkedPrefill", "PlanItem", "StepPlan",
    "make", "make_discipline", "as_scheduling_policy",
    # v1 deprecation shim
    "AdmissionPolicy",
    "simulate", "simulate_online",
    "SimResult", "run_fcfs_continuous", "run_multi_instance", "run_planned",
    "run_priority_continuous",
]
