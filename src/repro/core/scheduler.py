"""Algorithm 2 — SLO-aware multi-instance scheduling.

Flow (paper §4.4): predict request latencies → assign requests to instances
round-robin by largest remaining memory (Eq. 20 token accounting) →
per-instance priority mapping (Algorithm 1, embarrassingly parallel) →
enqueue → dispatch batches as instances become ready.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.annealing import SAParams, SAResult, priority_mapping
from repro.core.events import SimResult, simulate
from repro.core.latency_model import LinearLatencyModel
from repro.core.objective import evaluate
from repro.core.policies import (ExecutionDiscipline, InstanceState,
                                 MemoryGreedyMapper, PlannedPolicy)
from repro.core.profiler import MemoryModel, OutputLengthPredictor
from repro.core.slo import Request, as_arrays


@dataclasses.dataclass
class InstanceQueue:
    """A priority-ordered queue of planned batches for one LLM instance."""
    instance_id: int
    batches: List[List[Request]] = dataclasses.field(default_factory=list)

    def pop_next_batch(self) -> Optional[List[Request]]:
        return self.batches.pop(0) if self.batches else None

    def __len__(self):
        return sum(len(b) for b in self.batches)


@dataclasses.dataclass
class ScheduleOutcome:
    queues: List[InstanceQueue]
    predicted_G: float
    sa_results: List[SAResult]
    assignment: Dict[int, int]     # req_id -> instance


class SLOAwareScheduler:
    """The decoupled scheduler component.

    Parameters
    ----------
    model : fitted latency predictor (per instance type)
    num_instances : number of LLM serving instances
    max_batch : maximum batch size the service allows
    memory : Eq. 20 memory model (per instance)
    output_predictor : fills Request.predicted_output_len when missing
    mapper : priority-mapping implementation; defaults to the Python
             simulated annealer (Algorithm 1). ``use_jax=True`` switches to
             the jitted parallel-tempering annealer and batches ALL
             instances through one vmapped program
             (``annealing_jax.priority_mapping_multi_jax``);
             ``sa_params.incremental`` picks its incremental-Δ or
             full-evaluate scoring (see docs/annealer.md).
    """

    def __init__(self, model: LinearLatencyModel, num_instances: int = 1,
                 max_batch: int = 8,
                 memory: Optional[MemoryModel] = None,
                 output_predictor: Optional[OutputLengthPredictor] = None,
                 sa_params: Optional[SAParams] = None,
                 use_jax: bool = False):
        self.model = model
        self.num_instances = num_instances
        self.max_batch = max_batch
        self.memory = memory or MemoryModel(total_memory=float("inf"),
                                            mu=0.9, sigma_per_token=1.0)
        self.output_predictor = output_predictor
        # None sentinel: a module-level SAParams() default would be one
        # shared mutable instance across every scheduler ever constructed
        self.sa_params = sa_params if sa_params is not None else SAParams()
        self.use_jax = use_jax
        self._jax_cfg = None
        if use_jax:
            # map SAParams onto the jitted annealer's config (one
            # temperature schedule AND one proposal budget for both
            # backends) up front: a jit-unsupported ablation config
            # should fail at construction, not inside schedule()
            from repro.core.annealing_jax import config_from_sa_params
            self._jax_cfg = config_from_sa_params(self.sa_params)

    # ------------------------------------------------ instance assignment
    def assign_instances(self, requests: Sequence[Request]
                         ) -> List[List[Request]]:
        """Round-robin to the instance with the largest remaining memory;
        reset when the fullest instance cannot take the next request.
        Delegates to the shared :class:`~repro.core.policies.
        MemoryGreedyMapper` — the same object the serving
        ``EngineFleet`` can route through — so simulation and real
        serving assign by one code path."""
        states = [InstanceState(instance_id=i)
                  for i in range(self.num_instances)]
        assign = MemoryGreedyMapper(self.memory).map_batch(requests, states)
        buckets: List[List[Request]] = [[] for _ in range(self.num_instances)]
        for req, inst in zip(requests, assign):
            buckets[inst].append(req)
        return buckets

    # ------------------------------------------------ main entry
    def schedule(self, requests: Sequence[Request]) -> ScheduleOutcome:
        requests = list(requests)
        for r in requests:
            if r.predicted_output_len is None:
                if self.output_predictor is not None:
                    r.predicted_output_len = self.output_predictor.predict(
                        r.task_type)
                elif r.output_len is not None:
                    r.predicted_output_len = r.output_len
        buckets = self.assign_instances(requests)
        arrays_of = [as_arrays(b) if b else None for b in buckets]
        jax_results = None
        if self.use_jax:
            # ONE jitted program anneals every instance: vmap over
            # (instances × chains) with ragged loads padded and masked
            from repro.core.annealing_jax import priority_mapping_multi_jax
            jax_results = iter(priority_mapping_multi_jax(
                [a for a in arrays_of if a is not None], self.model,
                self.max_batch, self._jax_cfg, seed=self.sa_params.seed,
                incremental=self.sa_params.incremental))
        queues, sa_results = [], []
        assignment = {}
        g_num, g_den = 0.0, 0.0
        for inst, bucket in enumerate(buckets):
            q = InstanceQueue(inst)
            if bucket:
                arrays = arrays_of[inst]
                if jax_results is not None:
                    res = SAResult(*next(jax_results), -1, False)
                else:
                    res = priority_mapping(arrays, self.model,
                                           self.max_batch, self.sa_params)
                sa_results.append(res)
                ev = evaluate(arrays, self.model, res.perm, res.batch_id)
                g_num += ev.n_met
                g_den += ev.total_latency
                nb = int(res.batch_id[-1]) + 1
                for b in range(nb):
                    members = [bucket[i] for i, bi in
                               zip(res.perm, res.batch_id) if bi == b]
                    q.batches.append(members)
                for r in bucket:
                    assignment[r.req_id] = inst
            queues.append(q)
        return ScheduleOutcome(
            queues=queues,
            predicted_G=g_num / g_den if g_den else 0.0,
            sa_results=sa_results,
            assignment=assignment,
        )

    # ------------------------------------------------ plan evaluation
    def evaluate_plan(self, outcome: ScheduleOutcome,
                      discipline: "str | ExecutionDiscipline | None" = None,
                      noise_sigma: float = 0.0,
                      seed: int = 0) -> SimResult:
        """Execute a planned schedule through the discrete-event core
        under a chosen :class:`ExecutionDiscipline` — so a plan can be
        scored under stalling *and* chunked prefill before dispatching
        it to real engines.  Returns the merged multi-instance result."""
        out = SimResult({}, {}, {}, {})
        for q in outcome.queues:
            if not q.batches:
                continue
            ordered = [r for b in q.batches for r in b]
            rng = np.random.default_rng(seed + 1000 * q.instance_id)
            out = out.merged_with(simulate(
                ordered, self.model, self.max_batch,
                PlannedPolicy(q.batches), respect_arrivals=False,
                noise_sigma=noise_sigma, rng=rng, discipline=discipline))
        return out
