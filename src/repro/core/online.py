"""Online SLO-aware scheduling (beyond-paper extension).

The paper schedules a static request pool.  Real services see arrivals
over time; this module adds an event-driven wrapper: whenever the engine
frees a slot (or new requests arrive while slots are free), the waiting
queue is RE-ANNEALED with Algorithm 1 — deadline slack shrinks as requests
wait, so priorities must be recomputed, which the paper's decoupled design
makes cheap (the global-budget anneal is ~ms).

``simulate_online`` is a token-granularity discrete-event simulator with
Poisson-ish arrivals: at each admission point the SLO-aware policy anneals
the *remaining* queue (with SLOs tightened by elapsed waiting time) and
admits the head; the FCFS policy admits in arrival order.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.core.annealing import SAParams, priority_mapping
from repro.core.latency_model import LinearLatencyModel
from repro.core.simulator import SimResult
from repro.core.slo import Request, as_arrays, meets_slo


def _with_remaining_slo(r: Request, now: float) -> Request:
    """Shift e2e/TTFT budgets by the time already waited."""
    waited = max(0.0, now - r.arrival_time)
    slo = r.slo
    new = dataclasses.replace(
        slo,
        e2e=(slo.e2e - waited) if slo.e2e is not None else None,
        ttft=(slo.ttft - waited) if slo.ttft is not None else None)
    return dataclasses.replace(r, slo=new)


def simulate_online(requests: Sequence[Request], model: LinearLatencyModel,
                    max_batch: int, policy: str = "slo",
                    sa_params: Optional[SAParams] = None,
                    reanneal_min_queue: int = 2) -> SimResult:
    """policy: "slo" (re-annealed priorities) or "fcfs".

    Requests carry ``arrival_time``; metrics are relative to arrival.
    """
    sa_params = sa_params or SAParams(seed=0)
    res = SimResult({}, {}, {}, {})
    clock = 0.0
    pending: List[Request] = []
    future = sorted(requests, key=lambda r: r.arrival_time)
    active = []

    def admit_order():
        if policy == "fcfs" or len(pending) < reanneal_min_queue:
            return list(range(len(pending)))
        shifted = [_with_remaining_slo(r, clock) for r in pending]
        arrays = as_arrays(shifted)
        sa = priority_mapping(arrays, model, max_batch, sa_params)
        return list(sa.perm)

    while future or pending or active:
        # move arrivals whose time has come
        while future and future[0].arrival_time <= clock:
            pending.append(future.pop(0))
        # admit in policy order
        free = max_batch - len(active)
        if free > 0 and pending:
            order = admit_order()
            take = order[:free]
            admitted = [pending[i] for i in take]
            for i in sorted(take, reverse=True):
                pending.pop(i)
            b = len(admitted)
            pf = max(model.prefill_time(b, r.input_len) for r in admitted)
            clock += pf
            for r in admitted:
                lo = r.output_len if r.output_len is not None \
                    else r.planning_output_len()
                active.append({"req": r, "accum": r.input_len,
                               "remaining": max(int(lo), 1), "ttft": clock,
                               "gen": 0})
        if not active:
            if future:
                clock = max(clock, future[0].arrival_time)
            continue
        b = len(active)
        step = max(model.per_token_decode_time(b, a["accum"])
                   for a in active)
        clock += step
        done = [a for a in active if a["remaining"] <= 1]
        for a in active:
            a["accum"] += 1
            a["gen"] += 1
            a["remaining"] -= 1
        for a in done:
            active.remove(a)
            r = a["req"]
            e2e = clock - r.arrival_time
            ttft = a["ttft"] - r.arrival_time
            tpot = (clock - a["ttft"]) / max(a["gen"], 1)
            res.e2e[r.req_id] = e2e
            res.ttft[r.req_id] = ttft
            res.tpot[r.req_id] = tpot
            res.met[r.req_id] = meets_slo(r, e2e, ttft, tpot)
    return res
