"""Online SLO-aware scheduling (beyond-paper extension).

The paper schedules a static request pool.  Real services see arrivals
over time; whenever an instance frees a slot (or new requests arrive while
slots are free), the waiting queue is RE-ANNEALED with Algorithm 1 —
deadline slack shrinks as requests wait, so priorities must be recomputed.
The incremental-Δ annealer (``objective.IncrementalEvaluator``) makes this
cheap enough to run at every admission event.

The execution loop lives in :mod:`repro.core.events` (the unified
discrete-event core): ``simulate_online`` is a thin wrapper that picks the
scheduling policy (v2 API — ``"slo"`` re-anneal, ``"slo-preempt"``
multi-SLO preemption, or ``"fcfs"``), optionally an execution discipline
(``"stall"`` / ``"chunked:N"``), and — new with the unified core — can
spread arrivals over ``num_instances`` parallel instances draining one
shared queue.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

from repro.core.annealing import SAParams
from repro.core.events import (FCFSPolicy, SimResult,  # noqa: F401
                               SLOReannealPolicy, simulate)
from repro.core.latency_model import LinearLatencyModel
from repro.core.policies import (ExecutionDiscipline, SchedulingPolicy,
                                 make)
from repro.core.slo import Request

_ALIASES = {"slo": "slo-reanneal"}


def simulate_online(requests: Sequence[Request], model: LinearLatencyModel,
                    max_batch: int,
                    policy: Union[str, SchedulingPolicy] = "slo",
                    sa_params: Optional[SAParams] = None,
                    reanneal_min_queue: int = 2,
                    num_instances: int = 1,
                    discipline: Union[str, ExecutionDiscipline,
                                      None] = None) -> SimResult:
    """policy: "slo" (re-annealed priorities), "slo-preempt" (multi-SLO
    preemption), "fcfs", or any :class:`SchedulingPolicy` object.

    Requests carry ``arrival_time``; metrics are relative to arrival.
    """
    if isinstance(policy, str):
        policy = make(_ALIASES.get(policy, policy), model=model,
                      max_batch=max_batch,
                      sa_params=sa_params if sa_params is not None
                      else SAParams(seed=0),
                      min_queue=reanneal_min_queue)
    return simulate(requests, model, max_batch, policy,
                    num_instances=num_instances, respect_arrivals=True,
                    discipline=discipline)
