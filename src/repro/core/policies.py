"""Scheduling API v2 — composable policies and execution disciplines.

The v1 API (``AdmissionPolicy.select(pending, now, free, active_count)``)
could only *pick from the pending queue*: policies saw nothing about the
requests already running, could not evict them, and the execution mode
(whether prefill stalls running decodes or interleaves with them) was
hard-wired into each executor.  Multi-SLO serving needs all three knobs
(SLOs-Serve, arXiv 2504.08784; Sarathi-style chunking), so v2 splits the
contract into two composable abstractions shared verbatim by the
discrete-event core (:func:`repro.core.events.simulate`) and the real
serving engine (``repro.engine.engine.Engine.run_policy``):

``SchedulingPolicy``
    receives a :class:`SchedulerView` — the pending queue, the *active*
    set (with generated/remaining token counts and predicted slack under
    the latency model), the instance id, clock, and free slots — and
    returns a :class:`Decision` with ``admit`` indices into the pending
    queue and ``preempt`` indices into the active set.  Preempted
    requests return to pending with their KV cache discarded; on
    re-admission the context (prompt + tokens generated so far) is
    re-prefilled, and both executors charge that recompute honestly.

``ExecutionDiscipline``
    governs how admitted prefills interleave with running decode rounds:
    :class:`StallingPrefill` (whole-prompt prefill, running decodes
    stall) vs :class:`ChunkedPrefill` (the prompt is processed in
    ``chunk_size`` chunks, one chunk per tick — Sarathi-style).  Each
    scheduling tick the discipline emits a :class:`StepPlan` — a mixed
    batch of :class:`PlanItem` work units (``prefill-chunk(slot,
    span)`` / ``full-prefill(slot)`` / ``decode(slot)``) — through
    :meth:`ExecutionDiscipline.plan_step`, and every executor (the
    event core, ``Engine.run_policy``, the streaming ``ServeLoop``)
    runs exactly one plan per tick, so a prefill chunk rides in the
    same tick as the running decodes instead of stalling them.

Policies and disciplines are constructible by string key through the
registry (:func:`make`), e.g. ``make("slo-preempt", model=m)``,
``make("chunked:64")``, or ``make("slo-reanneal:jax", model=m,
max_batch=8)`` (online re-annealing on the jitted annealer backend), so
launchers and benchmarks can select them from the command line.

The v1 ``AdmissionPolicy`` name survives for one release as a thin
deprecation shim: subclasses implementing ``select`` are adapted into
``decide`` automatically (admit-only, no preemption).
"""
from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.annealing import SAParams, priority_mapping
from repro.core.latency_model import LinearLatencyModel
from repro.core.slo import Request, as_arrays

# ----------------------------------------------------------------- views
@dataclasses.dataclass(frozen=True)
class ActiveView:
    """One running request as a scheduling policy sees it."""
    request: Request
    generated: int          # tokens generated so far
    remaining: int          # tokens still to generate
    context_len: int        # l_i + generated (current KV length)
    ttft: Optional[float]   # absolute clock of the first token (None: n/a)
    now: float              # clock the view was built at
    e2e_base: float         # clock origin of the request's e2e budget
    batch: int              # batch size used for the slack projection
    model: Optional[LinearLatencyModel]
    # KV blocks ONLY this request holds in the paged pool — pages shared
    # with other requests or pinned by the prefix index are excluded,
    # because evicting this request would not free them (0: unpaged)
    blocks_held: int = 0

    @functools.cached_property
    def slack(self) -> float:
        """Predicted deadline slack (s); +inf if no applicable SLO.
        Computed lazily — non-preemptive policies never pay for it."""
        return compute_slack(self.request, generated=self.generated,
                             remaining=self.remaining,
                             context_len=self.context_len, now=self.now,
                             ttft=self.ttft, e2e_base=self.e2e_base,
                             batch=self.batch, model=self.model)


@dataclasses.dataclass(frozen=True)
class SchedulerView:
    """Everything a :class:`SchedulingPolicy` may look at for one decision."""
    pending: Tuple[Request, ...]
    active: Tuple[ActiveView, ...]
    now: float              # the deciding instance's clock
    free: int               # free slots before any preemption
    max_batch: int
    instance_id: int = 0
    # tokens already generated per pending entry (non-zero only for
    # re-queued preempted requests, whose re-prefill covers them too)
    pending_generated: Tuple[int, ...] = ()
    # the ExecutionDiscipline the executor will run admissions under —
    # lets policies price prefill honestly (chunked prefill interleaves
    # decode rounds, so it lands later than a stalling prefill would)
    discipline: Optional["ExecutionDiscipline"] = None
    # block-pool occupancy (paged-KV executors only; None/0 elsewhere):
    # policies that see these can make admission/eviction memory-aware
    free_blocks: Optional[int] = None
    total_blocks: Optional[int] = None
    block_size: int = 0
    # pages covering one slot's ring — a request can never hold more
    # (windowed slots wrap), so block-need estimates are capped by it
    pages_per_slot: int = 0
    # cached-prefix tokens per pending entry (shared-prefix KV reuse):
    # the executor's prefix index already holds that span's pages, so
    # admission prices only the unique new tokens/blocks.  Empty when
    # the executor has no prefix cache; falls back to
    # ``Request.cached_prefix`` (workload/simulator metadata).
    pending_cached: Tuple[int, ...] = ()

    def pending_context_len(self, i: int) -> int:
        """Context length if ``pending[i]`` were admitted now (prompt +
        carried generated tokens; decode attends all of it)."""
        gen = self.pending_generated[i] \
            if i < len(self.pending_generated) else 0
        return self.pending[i].input_len + gen

    def pending_cached_len(self, i: int) -> int:
        """Cached-prefix tokens of ``pending[i]`` — KV the executor can
        alias, skipping that span of prefill.  Clipped below the context
        length so at least one token is always priced as computed."""
        if i < len(self.pending_cached):
            cp = self.pending_cached[i]
        else:
            cp = int(getattr(self.pending[i], "cached_prefix", 0) or 0)
        return min(max(cp, 0), self.pending_context_len(i) - 1)

    def pending_prefill_len(self, i: int) -> int:
        """Tokens the prefill of ``pending[i]`` would actually compute:
        context minus the cached prefix."""
        return self.pending_context_len(i) - self.pending_cached_len(i)

    def blocks_for(self, tokens: int) -> int:
        """KV blocks covering ``tokens`` (0 on unpaged executors),
        capped at one slot's ring — matching the executor's own
        reservation (``Engine._blocks_needed``)."""
        if self.block_size <= 0:
            return 0
        n = -(-int(tokens) // self.block_size)
        return min(n, self.pages_per_slot) if self.pages_per_slot else n

    def pending_blocks(self, i: int) -> int:
        """*Unique new* blocks ``pending[i]`` needs if admitted now: its
        prefill context plus its (predicted) output budget, minus the
        blocks its cached prefix already aliases — shared pages cost the
        pool nothing, so memory admission must not charge for them."""
        r = self.pending[i]
        try:
            out = int(r.planning_output_len())
        except (AttributeError, ValueError):
            out = 0
        gen = self.pending_generated[i] \
            if i < len(self.pending_generated) else 0
        need = self.blocks_for(r.input_len + max(out, gen + 1))
        if self.block_size > 0:
            need -= self.pending_cached_len(i) // self.block_size
        return max(need, 0)


@dataclasses.dataclass
class Decision:
    """``admit``: indices into ``view.pending`` in admission order (the
    executor truncates to the slots available after preemption).
    ``preempt``: indices into ``view.active`` to evict first (KV
    discarded; the request returns to pending and is re-prefilled)."""
    admit: List[int] = dataclasses.field(default_factory=list)
    preempt: List[int] = dataclasses.field(default_factory=list)


# ------------------------------------------------------------ step plans
@dataclasses.dataclass(frozen=True)
class PlanItem:
    """One unit of work inside a :class:`StepPlan`.

    ``kind`` is ``"prefill"`` (compute ``length`` context tokens of
    ``ref``'s staged prefill, starting at position ``start``) or
    ``"decode"`` (one decode token for ``ref``).  ``ref`` is whatever
    the executor uses to name in-flight work — a slot id for the
    engine/serving loop, an index into the prefilling list for the
    event core.  ``last`` marks the chunk that completes a prefill:
    the request activates this tick and joins the same tick's decode
    round (its first token samples from this chunk's logits)."""
    kind: str
    ref: int
    start: int = 0
    length: int = 0
    last: bool = False


@dataclasses.dataclass(frozen=True)
class StepPlan:
    """One tick's mixed batch of work items, as emitted by
    :meth:`ExecutionDiscipline.plan_step`.  Executors run the prefill
    items first (each is one timed jit call / one priced model term),
    then a single decode round over every running request — including
    any whose ``last`` chunk just completed."""
    items: Tuple[PlanItem, ...] = ()

    @property
    def prefills(self) -> Tuple[PlanItem, ...]:
        return tuple(it for it in self.items if it.kind == "prefill")

    @property
    def decodes(self) -> Tuple[PlanItem, ...]:
        return tuple(it for it in self.items if it.kind == "decode")

    @property
    def prefill_tokens(self) -> int:
        return sum(it.length for it in self.items if it.kind == "prefill")

    @property
    def mixed(self) -> bool:
        """True when prefill work and running decodes share this tick —
        the stall-free batch shape chunked disciplines exist for."""
        return bool(self.prefills) and bool(self.decodes)

    def __bool__(self):
        return bool(self.items)


def compute_slack(request: Request, *, generated: int, remaining: int,
                  context_len: int, now: float, ttft: Optional[float],
                  e2e_base: float, batch: int,
                  model: Optional[LinearLatencyModel]) -> float:
    """Predicted deadline slack of a *running* request.

    Slack = earliest applicable deadline − predicted finish time, where
    the finish time is ``now`` plus the modelled decode time of the
    remaining tokens at the current batch size.  A TTFT-only request that
    already emitted its first token has infinite slack (it cannot miss
    anymore); without a latency model the remaining work is treated as
    free (slack degrades to remaining budget).
    """
    if model is None or remaining <= 0:
        finish = now
    else:
        finish = now + model.decode_time(max(batch, 1), context_len,
                                         remaining)
    deadlines = []
    if request.slo.e2e is not None:
        deadlines.append(e2e_base + request.slo.e2e)
    if request.slo.tpot is not None and ttft is not None:
        total = max(generated + remaining, 1)
        deadlines.append(ttft + request.slo.tpot * total)
    if not deadlines:
        return math.inf
    return min(deadlines) - finish


def make_active_view(request: Request, generated: int, remaining: int,
                     context_len: int, now: float, ttft: Optional[float],
                     e2e_base: float, batch: int,
                     model: Optional[LinearLatencyModel],
                     blocks_held: int = 0) -> ActiveView:
    """Build one :class:`ActiveView` — shared by the event core and the
    engine so both expose identical state to policies."""
    return ActiveView(request=request, generated=generated,
                      remaining=remaining, context_len=context_len,
                      ttft=ttft, now=now, e2e_base=e2e_base, batch=batch,
                      model=model, blocks_held=blocks_held)


def submit_base(r: Request) -> float:
    """The clock origin for a request's waited time / SLO budgets.

    ``submit_time`` is stamped by whichever executor runs the request (on
    *its* clock); ``arrival_time`` is the workload-relative fallback.
    Mixing the two was the v1 clock-mismatch bug: a warm engine clock
    minus a workload-relative arrival looked like hours of waiting.
    """
    return r.submit_time if r.submit_time is not None else r.arrival_time


def with_remaining_slo(r: Request, now: float) -> Request:
    """Shift e2e/TTFT budgets by the time already waited (one clock)."""
    waited = max(0.0, now - submit_base(r))
    slo = r.slo
    new = dataclasses.replace(
        slo,
        e2e=(slo.e2e - waited) if slo.e2e is not None else None,
        ttft=(slo.ttft - waited) if slo.ttft is not None else None)
    return dataclasses.replace(r, slo=new)


# ------------------------------------------------- pending-request pricing
def discipline_prefill_cost(view: SchedulerView,
                            model: LinearLatencyModel, ctx: int,
                            cached: int = 0) -> float:
    """Time from admission to first token for a ``ctx``-token prefill
    under the view's discipline: whole-prompt prefill, or — chunked —
    the chunk sum plus the decode rounds for the running batch between
    chunks.  ``cached`` tokens (an aliased prefix) are skipped entirely.
    Shared by every pricing policy so they all charge admission the way
    the executor will actually run it."""
    ctx = ctx - min(max(cached, 0), ctx - 1)
    C = getattr(view.discipline, "chunk_size", 0)
    if C <= 0:
        return model.prefill_time(1, ctx)
    chunks = [min(C, ctx - i) for i in range(0, ctx, C)]
    cost = sum(model.prefill_time(1, c) for c in chunks)
    if view.active and len(chunks) > 1:
        b = len(view.active)
        cost += (len(chunks) - 1) * max(
            model.per_token_decode_time(b, v.context_len)
            for v in view.active)
    return cost


def pending_budget(view: SchedulerView, i: int) -> float:
    """Remaining time until ``pending[i]``'s tightest *live* deadline
    (+inf with no applicable SLO).  A re-queued preempted request
    already emitted its first token, so its TTFT constraint is settled
    — only its e2e deadline stays live."""
    r = view.pending[i]
    waited = max(0.0, view.now - submit_base(r))
    cands = []
    if r.slo.ttft is not None and view.pending_context_len(i) == \
            r.input_len:
        cands.append(r.slo.ttft - waited)
    if r.slo.e2e is not None:
        cands.append(r.slo.e2e - waited)
    return min(cands) if cands else math.inf


def pending_service(view: SchedulerView, i: int,
                    model: LinearLatencyModel) -> float:
    """Modelled solo service time of ``pending[i]`` if admitted now:
    prefill under the view's discipline (cached prefix skipped) plus the
    decode of its remaining output tokens.  Requests without an output
    estimate price decode as free (prefill-only)."""
    r = view.pending[i]
    ctx = view.pending_context_len(i)
    prefill = discipline_prefill_cost(view, model, ctx,
                                      view.pending_cached_len(i))
    try:
        gen = ctx - r.input_len
        # prefill emits one token; the rest are decode rounds
        rem = max(int(r.planning_output_len()) - gen - 1, 0)
        decode = model.decode_time(1, ctx, rem)
    except ValueError:                       # no output-length estimate
        decode = 0.0
    return prefill + decode


# -------------------------------------------------------------- policies
class SchedulingPolicy:
    """v2 contract: ``decide(view) -> Decision``.

    ``preemptive`` tells executors whether to consult the policy even
    when no slot is free (preemption is the only useful decision then).
    ``reset()`` is called by both executors at the start of every run so
    stateful policies (e.g. :class:`PlannedPolicy`) are reusable.
    """

    preemptive = False

    def decide(self, view: SchedulerView) -> Decision:
        raise NotImplementedError

    def reset(self) -> None:
        pass


class FCFSPolicy(SchedulingPolicy):
    """vLLM-like continuous batching: admit in arrival (list) order.

    Also serves the planned-*priority* path: the scheduler's priority
    order is applied upstream by flattening the planned batches."""

    def decide(self, view):
        return Decision(admit=list(range(min(view.free, len(view.pending)))))


class PlannedPolicy(SchedulingPolicy):
    """Execute planned batches sequentially with a barrier (the paper's
    dispatch discipline): the next batch is admitted only once the
    instance drained completely.  ``reset()`` rewinds the batch cursor,
    so one policy object can drive several runs."""

    def __init__(self, batches: Sequence[Sequence]):
        self._batches = [[getattr(r, "req_id", r) for r in b]
                         for b in batches if len(b)]
        self._next = 0

    def reset(self):
        self._next = 0

    def decide(self, view):
        if len(view.active) > 0 or self._next >= len(self._batches):
            return Decision()
        batch = self._batches[self._next]
        pos = {r.req_id: i for i, r in enumerate(view.pending)}
        if any(rid not in pos for rid in batch):
            return Decision()               # members not yet arrived
        if len(batch) > view.free:
            raise RuntimeError("slot pool smaller than planned batch")
        self._next += 1
        return Decision(admit=[pos[rid] for rid in batch])


class SLOReannealPolicy(SchedulingPolicy):
    """Re-anneal the waiting queue with Algorithm 1 at every admission
    event, with SLO budgets shrunk by the time each request already
    waited (on the executor's clock, via ``submit_time``).  The
    incremental-Δ annealer keeps this cheap enough to run on the
    admission hot path (paper Table 1).

    ``backend`` picks the annealer: ``"python"`` (default — the
    ``objective.IncrementalEvaluator`` hot loop) or ``"jax"`` (the jitted
    incremental annealer, ``annealing_jax.priority_mapping_jax`` — queue
    depths are bucketed to powers of two so shifting queues reuse a few
    compilations; see docs/annealer.md for when each backend wins)."""

    def __init__(self, model: LinearLatencyModel, max_batch: int,
                 sa_params: Optional[SAParams] = None, min_queue: int = 2,
                 backend: str = "python"):
        if backend not in ("python", "jax"):
            raise ValueError(
                f"backend must be 'python' or 'jax', got {backend!r}")
        self.model = model
        self.max_batch = max_batch
        self.sa_params = sa_params if sa_params is not None \
            else SAParams(seed=0)
        self.min_queue = min_queue
        self.backend = backend
        self._jax_cfg = None
        if backend == "jax":
            # validate the SAParams mapping up front — a jit-unsupported
            # ablation config should fail at construction, not mid-run
            # on the first admission event that reaches min_queue
            from repro.core.annealing_jax import config_from_sa_params
            self._jax_cfg = config_from_sa_params(self.sa_params)

    def _anneal_perm(self, arrays) -> List[int]:
        if self.backend == "jax":
            from repro.core.annealing_jax import priority_mapping_jax
            p = self.sa_params
            perm, _, _ = priority_mapping_jax(
                arrays, self.model, self.max_batch, self._jax_cfg,
                seed=p.seed, incremental=p.incremental)
            return [int(i) for i in perm]
        sa = priority_mapping(arrays, self.model, self.max_batch,
                              self.sa_params)
        return [int(i) for i in sa.perm]

    def decide(self, view):
        pending = view.pending
        if len(pending) < self.min_queue:
            return Decision(admit=list(range(min(view.free, len(pending)))))
        shifted = [with_remaining_slo(r, view.now) for r in pending]
        return Decision(admit=self._anneal_perm(as_arrays(shifted)))


class SLOPreemptPolicy(SchedulingPolicy):
    """Multi-SLO preemption (SLOs-Serve style): when a tight-SLO arrival
    would miss its first-token deadline waiting for a natural slot, evict
    the active request with the largest positive slack — provided that
    victim can still absorb its own re-prefill (KV recompute) cost.

    Admission order is urgency-first (smallest remaining TTFT/e2e
    budget).  Requests without a first-token-sensitive SLO never trigger
    an eviction.

    On a paged-KV executor (``view.free_blocks`` is set) the policy is
    memory-aware: admissions are filtered to what the free blocks cover,
    a tight arrival short on *blocks* (not just slots) may trigger
    eviction, victims are ranked by **freed blocks per unit of slack**
    (most memory recovered at least deadline risk; no-SLO victims rank
    first), and several victims may be evicted for one large arrival.
    """

    preemptive = True

    def __init__(self, model: LinearLatencyModel, margin: float = 0.0):
        self.model = model
        self.margin = margin

    def _budget(self, view: SchedulerView, i: int) -> float:
        """Remaining time until ``pending[i]``'s tightest live deadline
        (see :func:`pending_budget`)."""
        return pending_budget(view, i)

    def _prefill_cost(self, view: SchedulerView, ctx: int,
                      cached: int = 0) -> float:
        """Discipline-aware time-to-first-token (see
        :func:`discipline_prefill_cost`)."""
        return discipline_prefill_cost(view, self.model, ctx, cached)

    def _constraints(self, view: SchedulerView, i: int):
        """(remaining budget, modelled service time) per applicable live
        SLO of ``pending[i]`` if admitted now.  TTFT needs the prefill;
        e2e needs prefill + the decode of its remaining output tokens."""
        r = view.pending[i]
        waited = max(0.0, view.now - submit_base(r))
        ctx = view.pending_context_len(i)
        prefill = self._prefill_cost(view, ctx, view.pending_cached_len(i))
        out = []
        if r.slo.ttft is not None and ctx == r.input_len:
            out.append((r.slo.ttft - waited, prefill))
        if r.slo.e2e is not None:
            try:
                gen = ctx - r.input_len
                # prefill emits one token; the rest are decode rounds
                rem = max(int(r.planning_output_len()) - gen - 1, 0)
                decode = self.model.decode_time(1, ctx, rem)
            except ValueError:              # no output-length estimate
                decode = 0.0
            out.append((r.slo.e2e - waited, prefill + decode))
        return out, prefill

    def _victim_order(self, view: SchedulerView) -> List[int]:
        if view.free_blocks is None:
            return sorted(range(len(view.active)),
                          key=lambda j: view.active[j].slack, reverse=True)

        # memory-aware ranking: blocks freed per unit of slack consumed.
        # No-SLO victims (infinite slack) are free memory — rank first,
        # largest holdings first; non-positive slack ranks last (the
        # absorb guard rejects those anyway).
        def vkey(j):
            v = view.active[j]
            if v.slack == math.inf:
                return (2, v.blocks_held)
            if v.slack > 0:
                return (1, v.blocks_held / v.slack)
            return (0, v.slack)
        return sorted(range(len(view.active)), key=vkey, reverse=True)

    def decide(self, view):
        if not view.pending:
            return Decision()
        budgets = [self._budget(view, i) for i in range(len(view.pending))]
        order = sorted(range(len(view.pending)), key=budgets.__getitem__)
        avail = view.free_blocks            # None on unpaged executors
        admit: List[int] = []
        overflow: List[int] = []
        for i in order:
            need = view.pending_blocks(i) if avail is not None else 0
            if len(admit) < view.free and (avail is None or need <= avail):
                admit.append(i)
                if avail is not None:
                    avail -= need
            else:
                overflow.append(i)          # short a slot or short blocks
        preempt: List[int] = []
        victims = self._victim_order(view)
        vi = 0
        # modelled completion time of each running request: the k-th
        # arrival left waiting gets (at best) the k-th slot to free up
        b = max(len(view.active), 1)
        comps = {j: self.model.decode_time(b, v.context_len,
                                           max(v.remaining, 0))
                 for j, v in enumerate(view.active)}
        cons_cache = {i: self._constraints(view, i)
                      for i in range(len(view.pending))
                      if budgets[i] != math.inf}
        # a re-queued victim re-enters with the loosest budget, so every
        # deadline-bearing pending request runs before it: its slack must
        # absorb all of their service, not just the triggering arrival's
        urgent_service = sum(max((s for _, s in cons), default=0.0)
                             for cons, _ in cons_cache.values())
        queued = 0                          # arrivals left to wait so far
        for i in overflow:
            if budgets[i] == math.inf:
                break                       # sorted: the rest are ∞ too
            cons, _ = cons_cache[i]
            if any(bud < s + self.margin for bud, s in cons):
                queued += 1                 # doomed, but it still claims
                continue                    # a freeing slot later
            need = view.pending_blocks(i) if avail is not None else 0
            remaining = sorted(c for j, c in comps.items()
                               if j not in preempt)
            # when waiters outnumber running requests the true wait is
            # longer than any single completion; clamping to the last
            # one is optimistic but empirically stable — an unbounded
            # estimate here makes every overflow arrival demand an
            # eviction and the queue thrashes (att 1.0 -> 0.89 on the
            # contended benchmark)
            wait = remaining[min(queued, len(remaining) - 1)] \
                if remaining else 0.0
            # blocks, like slots, free naturally when runners finish — an
            # arrival that can afford the wait never triggers an eviction
            if all(bud >= wait + s + self.margin for bud, s in cons):
                queued += 1                 # makes it without eviction
                continue
            # evict from vi onward until the blocks are covered (one
            # victim always suffices on unpaged executors); every victim
            # in the chain must absorb its own recompute
            picked: List[int] = []
            freed = 0
            vj = vi
            ok = False
            while vj < len(victims):
                j = victims[vj]
                v = view.active[j]
                # a victim's cached prefix survives its eviction (the
                # index owns those pages), so its re-prefill skips it too
                recompute = self._prefill_cost(
                    view, v.request.input_len + v.generated,
                    int(getattr(v.request, "cached_prefix", 0) or 0))
                if not (v.slack > recompute + urgent_service + self.margin):
                    break                   # victims can't absorb THIS
                picked.append(j)            # arrival; try the next one
                freed += v.blocks_held
                vj += 1
                if avail is None or need <= avail + freed:
                    ok = True
                    break
            if not ok:
                queued += 1
                continue
            preempt.extend(picked)
            vi = vj
            if avail is not None:
                avail += freed - need
            admit.append(i)
        return Decision(admit=admit, preempt=preempt)


class IndexPolicy(SchedulingPolicy):
    """Theory-grounded priority-index admission ("Optimal Scheduling
    Algorithms for LLM Inference", arXiv 2508.01002): each pending
    request gets a closed-form index — no anneal — and the highest
    indices take the free slots.  Three members of the family share the
    machinery:

    ``w`` (default — the W-index)
        ``1 / (slack · service)`` where slack is the remaining deadline
        budget minus the modelled service time and service is the
        discipline-aware prefill plus remaining decode.  Urgent *and*
        short requests dominate; the index diverges as slack → 0, so a
        request is pulled forward exactly while pulling it forward can
        still save it.
    ``sjf``
        ``1 / service`` — shortest-remaining-service first (optimal for
        mean latency when nothing has a deadline).
    ``edf``
        ``-budget`` — earliest live deadline first.

    Under ``w`` requests are tiered: savable deadline-bearing requests
    (slack > 0) outrank no-deadline ones, which outrank the doomed
    (slack ≤ 0 — serving them cannot meet anything, so they yield to
    requests that can still be saved; within the doomed tier shortest
    first, to shed them cheapest).  Ties break on ``req_id`` so the
    admitted *set and order* are invariant to any permutation of the
    pending queue.

    On a paged executor the admission walk is block-aware: a request
    whose unique new blocks exceed the remaining free blocks is skipped
    — not a barrier — so smaller lower-index requests can still fill
    the pool.
    """

    def __init__(self, model: LinearLatencyModel, mode: str = "w",
                 eps: float = 1e-6):
        if mode not in ("w", "sjf", "edf"):
            raise ValueError(
                f"mode must be 'w', 'sjf' or 'edf', got {mode!r}")
        self.model = model
        self.mode = mode
        self.eps = eps

    def _index(self, view: SchedulerView, i: int) -> Tuple[int, float]:
        """(tier, index) of ``pending[i]`` — higher admits first."""
        service = max(pending_service(view, i, self.model), self.eps)
        if self.mode == "sjf":
            return (0, 1.0 / service)
        budget = pending_budget(view, i)
        if self.mode == "edf":
            return (0, -budget)
        if budget == math.inf:
            return (1, 1.0 / service)
        slack = budget - service
        if slack <= 0.0:
            return (0, 1.0 / service)
        return (2, 1.0 / (max(slack, self.eps) * service))

    def decide(self, view):
        def key(i):
            tier, idx = self._index(view, i)
            return (tier, idx, -getattr(view.pending[i], "req_id", i))
        order = sorted(range(len(view.pending)), key=key, reverse=True)
        avail = view.free_blocks            # None on unpaged executors
        admit: List[int] = []
        for i in order:
            if len(admit) >= view.free:
                break
            need = view.pending_blocks(i) if avail is not None else 0
            if avail is not None and need > avail:
                continue
            admit.append(i)
            if avail is not None:
                avail -= need
        return Decision(admit=admit)


# ------------------------------------------------------ v1 compatibility
class AdmissionPolicy(SchedulingPolicy):
    """Deprecated v1 base class (admit-only, no view of the active set).

    Subclasses implementing ``select(pending, now, free, active_count)``
    keep working — ``decide`` adapts the call — but should migrate to
    :class:`SchedulingPolicy`.  This shim is kept for one release.
    """

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        warnings.warn(
            f"{cls.__name__} subclasses the deprecated AdmissionPolicy; "
            "subclass SchedulingPolicy and implement decide(view) instead",
            DeprecationWarning, stacklevel=2)

    def select(self, pending: Sequence[Request], now: float, free: int,
               active_count: int) -> List[int]:
        raise NotImplementedError

    def decide(self, view):
        return Decision(admit=list(self.select(
            list(view.pending), view.now, view.free, len(view.active))))


class _LegacySelectAdapter(SchedulingPolicy):
    """Wraps a duck-typed v1 object (has ``select``, no ``decide``)."""

    def __init__(self, obj):
        self._obj = obj

    def reset(self):
        reset = getattr(self._obj, "reset", None)
        if reset is not None:
            reset()

    def decide(self, view):
        return Decision(admit=list(self._obj.select(
            list(view.pending), view.now, view.free, len(view.active))))


def resolve_policy(policy, **ctx) -> Tuple[SchedulingPolicy, bool]:
    """One policy-resolution protocol for every executor: coerce a
    registry key (built with the ``ctx`` kwargs) or a v1/v2 policy
    object into the v2 protocol, reset it for a fresh run, and report
    whether it can preempt.  Returns ``(policy, preemptive)``."""
    if isinstance(policy, str):
        policy = make(policy, **ctx)
    pol = as_scheduling_policy(policy)
    if hasattr(pol, "reset"):
        pol.reset()
    return pol, bool(getattr(pol, "preemptive", False))


def normalize_decision(dec: Decision, view: SchedulerView
                       ) -> Tuple[List[int], List[int]]:
    """Validate a policy's :class:`Decision` for an executor — one
    protocol for the event core and the engine.

    Returns ``(admit, preempt)``: both deduplicated and bounds-checked
    against the view; ``admit`` preserves the policy's order (the caller
    truncates to the slots available after preemption), ``preempt`` is
    reverse-sorted so victims can be popped from the active list without
    invalidating the remaining indices.
    """
    admit = [j for j in dict.fromkeys(dec.admit)
             if 0 <= j < len(view.pending)]
    preempt = sorted({j for j in dec.preempt if 0 <= j < len(view.active)},
                     reverse=True)
    return admit, preempt


def as_scheduling_policy(obj) -> SchedulingPolicy:
    """Coerce v1/v2 policy objects into the v2 protocol."""
    if isinstance(obj, SchedulingPolicy):
        return obj
    if hasattr(obj, "decide"):
        return obj
    if hasattr(obj, "select"):
        warnings.warn(
            f"{type(obj).__name__} only implements the deprecated "
            "select() protocol; implement decide(view) instead",
            DeprecationWarning, stacklevel=2)
        return _LegacySelectAdapter(obj)
    raise TypeError(f"{obj!r} is not a SchedulingPolicy (no decide/select)")


# ------------------------------------------------------------ disciplines
class ExecutionDiscipline:
    """How admitted prefills interleave with running decode rounds.

    ``chunk_size == 0`` means whole-prompt prefill (running decodes
    stall for the full span); ``chunk_size > 0`` means Sarathi-style
    chunking: each in-flight prefill advances one ``chunk_size`` chunk
    per tick, sharing the tick with the running batch's decode round.
    The same objects configure the event core, ``Engine.run_policy``
    and the streaming ``ServeLoop`` — all three drive the one
    plan/execute cycle through :meth:`plan_step`."""

    chunk_size: int = 0

    def plan_step(self, prefills: Sequence[Tuple[int, int, int]],
                  decodes: Sequence[int] = ()) -> StepPlan:
        """Emit one tick's :class:`StepPlan`.

        ``prefills`` is the in-flight prefill state as ``(ref, done,
        total)`` triples — ``done`` context tokens already computed of
        ``total`` (an aliased cached prefix counts as done).
        ``decodes`` is the refs of the running requests.  A stalling
        discipline emits the whole remaining span per prefill; a
        chunked one emits at most ``chunk_size`` tokens per prefill per
        tick (``chunk_size`` is re-read every call, so an adaptive
        discipline retuned mid-run takes effect on the next tick).
        Decode items always ride in the same plan: the executor runs
        one decode round after the prefill items, which is what makes
        the batch stall-free."""
        C = self.chunk_size
        items = []
        for ref, done, total in prefills:
            rem = int(total) - int(done)
            if rem <= 0:
                continue
            span = rem if C <= 0 else min(int(C), rem)
            items.append(PlanItem("prefill", int(ref), int(done), span,
                                  last=span >= rem))
        for ref in decodes:
            items.append(PlanItem("decode", int(ref), 0, 1))
        return StepPlan(tuple(items))

    def __repr__(self):
        return f"{type(self).__name__}()"


class StallingPrefill(ExecutionDiscipline):
    """Whole-prompt prefill; running decodes stall for its duration."""


class ChunkedPrefill(ExecutionDiscipline):
    """Chunked prefill: running decodes advance between chunks."""

    def __init__(self, chunk_size: int = 64):
        if chunk_size <= 0:
            raise ValueError("chunk_size must be positive")
        self.chunk_size = int(chunk_size)

    def __repr__(self):
        return f"ChunkedPrefill({self.chunk_size})"


class AdaptiveChunkedPrefill(ChunkedPrefill):
    """A :class:`ChunkedPrefill` whose ``chunk_size`` is rewritten per
    admission decision by :class:`DynamicChunkPolicy`.  Both executors
    re-read ``chunk_size`` at every admission, so a mutation takes
    effect on the very next prefill."""

    def __repr__(self):
        return f"AdaptiveChunkedPrefill({self.chunk_size})"


# ----------------------------------------------------- dynamic chunk size
class DynamicChunkPolicy(SchedulingPolicy):
    """SLOs-Serve-style per-admission dynamic chunk sizing (arXiv
    2504.08784): before delegating admission to a base policy, solve for
    the largest prefill chunk the running batch's TPOT headroom permits
    and write it into the (shared, mutable) chunked discipline.

    A chunk stalls every running decode for ``prefill_time(1, C)``, so
    the tightest running TPOT budget bounds the chunk:

        prefill_time(1, C) ≤ min_j (tpot_j − τ_d(b, ctx_j))
        ⇒  C = (head − β_p − δ_p) / (α_p + γ_p)

    clamped to ``[min_chunk, max_chunk]``.  With no TPOT-bearing request
    running, the chunk opens to ``max_chunk`` (prefill throughput);
    under decode pressure it shrinks toward ``min_chunk`` (tail TBT).

    The policy carries its own :class:`AdaptiveChunkedPrefill` in
    ``.discipline`` — hand that to the executor — and also rewrites any
    *other* chunked ``view.discipline`` it is handed, so admission
    pricing within the same decision sees the new size.  Admission is
    delegated to ``base`` (default: the W-index policy), which prices
    prefill under the freshly-set chunk.
    """

    def __init__(self, model: LinearLatencyModel,
                 base: Optional[SchedulingPolicy] = None,
                 min_chunk: int = 16, max_chunk: int = 512):
        if not 0 < int(min_chunk) <= int(max_chunk):
            raise ValueError("need 0 < min_chunk <= max_chunk")
        self.model = model
        self.base = base if base is not None else IndexPolicy(model)
        self.min_chunk = int(min_chunk)
        self.max_chunk = int(max_chunk)
        self.discipline = AdaptiveChunkedPrefill(self.max_chunk)

    @property
    def preemptive(self):
        return bool(getattr(self.base, "preemptive", False))

    def reset(self):
        self.discipline.chunk_size = self.max_chunk
        self.base.reset()

    def chunk_for(self, view: SchedulerView) -> int:
        """Largest chunk the running batch's TPOT headroom permits."""
        m = self.model
        b = max(len(view.active), 1)
        heads = [v.request.slo.tpot - m.per_token_decode_time(
                     b, v.context_len)
                 for v in view.active if v.request.slo.tpot is not None]
        if not heads:
            return self.max_chunk
        head = min(heads) - m.beta_p - m.delta_p
        denom = m.alpha_p + m.gamma_p
        if denom <= 0.0:                    # flat prefill cost in length
            return self.max_chunk if head > 0 else self.min_chunk
        return int(min(max(head / denom, self.min_chunk), self.max_chunk))

    def retune(self, view: SchedulerView) -> int:
        """Re-solve the chunk size for the *current* running batch and
        write it into the adaptive discipline(s).  Executors call this
        every tick where no admission decision runs (``decide`` retunes
        on its own), so the chunk tracks the batch's TPOT headroom
        tick-by-tick — opening up as tight requests drain, shrinking
        as they pile in — not just at admission instants."""
        C = self.chunk_for(view)
        self.discipline.chunk_size = C
        disc = view.discipline
        if disc is not None and disc is not self.discipline \
                and getattr(disc, "chunk_size", 0) > 0:
            disc.chunk_size = C
        return C

    def decide(self, view):
        self.retune(view)
        return self.base.decide(view)


# ------------------------------------------------------- instance mapping
@dataclasses.dataclass(frozen=True)
class InstanceState:
    """One serving instance as an :class:`InstanceMapper` sees it — a
    load snapshot the fleet (or the multi-instance scheduler) builds
    per routing decision.  Simulator callers that only need instance
    identities can leave the load fields at their defaults."""
    instance_id: int
    queue_depth: int = 0      # submitted but not yet running
    active: int = 0           # occupied engine slots
    free_slots: int = 0
    free_blocks: int = 0      # KV pool headroom (paged engines)
    active_tokens: int = 0    # live context tokens across running slots


class InstanceMapper:
    """Maps arriving requests onto serving instances (paper §4.4).

    One code path for both consumers: the real-serving ``EngineFleet``
    routes arrivals through :meth:`map_one` / :meth:`plan`, and the
    multi-instance scheduler's ``assign_instances`` (feeding
    ``run_multi_instance`` in the simulator) delegates to
    :meth:`map_batch` — so a mapper validated in simulation serves
    unchanged.

    ``map_batch`` returns one instance id per request, order-preserving
    over the input.  ``plan`` returns per-instance *submission orders*
    (lists of request indices): the default groups ``map_batch``'s
    assignment preserving arrival order, while planning mappers
    (:class:`AnnealedMapper`) reorder within each instance — the fleet
    submits in exactly this order, so a priority plan becomes the
    engines' FCFS admission order.
    """

    def map_batch(self, requests: Sequence[Request],
                  states: Sequence[InstanceState]) -> List[int]:
        raise NotImplementedError

    def map_one(self, request: Request,
                states: Sequence[InstanceState]) -> int:
        return self.map_batch([request], states)[0]

    def plan(self, requests: Sequence[Request],
             states: Sequence[InstanceState]) -> List[List[int]]:
        assign = self.map_batch(requests, states)
        by_inst: Dict[int, List[int]] = {s.instance_id: [] for s in states}
        for i, inst in enumerate(assign):
            by_inst[inst].append(i)
        return [by_inst[s.instance_id] for s in states]


class RoundRobinMapper(InstanceMapper):
    """Stateful round-robin — the trivial baseline."""

    def __init__(self):
        self._next = 0

    def map_batch(self, requests, states):
        out = []
        for _ in requests:
            out.append(states[self._next % len(states)].instance_id)
            self._next += 1
        return out


class LeastLoadedMapper(InstanceMapper):
    """Route to the instance with the fewest queued + running requests,
    counting assignments made earlier in the same batch; ties go to the
    lowest instance id."""

    def map_batch(self, requests, states):
        load = {s.instance_id: s.queue_depth + s.active for s in states}
        order = sorted(load)
        out = []
        for _ in requests:
            tgt = min(order, key=lambda i: (load[i], i))
            load[tgt] += 1
            out.append(tgt)
        return out


class SLOAffinityMapper(InstanceMapper):
    """Pin each SLO class (``task_type``) to a home instance — the
    SLOs-Serve-style per-class replica split (arXiv 2504.08784): a
    class's requests share prefixes and latency profiles, so keeping
    them together maximizes KV reuse and keeps the per-instance
    workload unimodal.  Classes are assigned round-robin on first
    sight; unseen-class spill goes least-loaded."""

    def __init__(self):
        self._home: Dict[str, int] = {}

    def map_batch(self, requests, states):
        ids = [s.instance_id for s in states]
        out = []
        for r in requests:
            cls = r.task_type
            if cls not in self._home:
                self._home[cls] = ids[len(self._home) % len(ids)]
            out.append(self._home[cls])
        return out


class MemoryGreedyMapper(InstanceMapper):
    """The paper's Algorithm-2 assignment step (Eq. 20): round-robin to
    the instance with the largest remaining memory, resetting the
    accounting when the fullest instance cannot take the next request
    (a maximal wave has been assigned)."""

    def __init__(self, memory=None):
        if memory is None:
            from repro.core.profiler import MemoryModel
            memory = MemoryModel(total_memory=float("inf"), mu=0.9,
                                 sigma_per_token=1.0)
        self.memory = memory

    def map_batch(self, requests, states):
        ids = [s.instance_id for s in states]
        remaining = {i: self.memory.total for i in ids}
        out = []
        for req in requests:
            need = self.memory.tokens_to_memory(
                req.input_len + req.planning_output_len())
            tgt = max(ids, key=lambda i: (remaining[i], -i))
            if remaining[tgt] < need:
                remaining = {i: self.memory.total for i in ids}
                tgt = max(ids, key=lambda i: (remaining[i], -i))
            remaining[tgt] -= need
            out.append(tgt)
        return out


class AnnealedMapper(InstanceMapper):
    """Full Algorithm 2: memory-greedy assignment then a per-instance
    Algorithm-1 priority anneal (``priority_mapping_multi_jax`` when
    ``use_jax`` — all instances × chains in one vmapped jit).  ``plan``
    returns each instance's annealed batch order, which the fleet
    replays as its submission order; ``map_batch`` exposes just the
    assignment for callers that ignore ordering."""

    def __init__(self, model, max_batch: int = 8, sa_params=None,
                 memory=None, use_jax: bool = True):
        self.model = model
        self.max_batch = max_batch
        self.sa_params = sa_params
        self.memory = memory
        self.use_jax = use_jax

    def _scheduler(self, n_instances: int):
        from repro.core.scheduler import SLOAwareScheduler
        return SLOAwareScheduler(self.model, num_instances=n_instances,
                                 max_batch=self.max_batch,
                                 memory=self.memory,
                                 sa_params=self.sa_params,
                                 use_jax=self.use_jax)

    def map_batch(self, requests, states):
        sched = self._scheduler(len(states))
        assignment = sched.schedule(list(requests)).assignment
        ids = [s.instance_id for s in states]
        return [ids[assignment[r.req_id]] for r in requests]

    def plan(self, requests, states):
        sched = self._scheduler(len(states))
        outcome = sched.schedule(list(requests))
        index_of = {id(r): i for i, r in enumerate(requests)}
        return [[index_of[id(r)] for b in q.batches for r in b]
                for q in outcome.queues]


def make_mapper(obj: "Union[str, InstanceMapper]", **kwargs
                ) -> InstanceMapper:
    """Coerce a registry key (``"least-loaded"``, ``"route:annealed"``)
    or mapper instance into an :class:`InstanceMapper`."""
    if isinstance(obj, InstanceMapper):
        return obj
    name = obj if obj.startswith("route:") else f"route:{obj}"
    out = make(name, **kwargs)
    if not isinstance(out, InstanceMapper):
        raise TypeError(f"{obj!r} is not an InstanceMapper")
    return out


# --------------------------------------------------------------- registry
_REGISTRY: Dict[str, Callable] = {}


def register(name: str):
    """Register a policy/discipline factory under a string key."""
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def make(name: Union[str, SchedulingPolicy, ExecutionDiscipline], **kwargs):
    """String-keyed factory for policies and disciplines.

    ``make("fcfs")``, ``make("slo-reanneal", model=m, max_batch=8)``,
    ``make("slo-preempt", model=m)``, ``make("planned", batches=...)``,
    ``make("stall")``, ``make("chunked", chunk_size=32)`` or the compact
    ``make("chunked:32")``.  Policy/discipline objects pass through
    unchanged, so every call site can accept either form.  Factories
    ignore context kwargs they don't need, letting callers pass one
    blanket context (model, max_batch, …).
    """
    if not isinstance(name, str):
        return name
    key, _, suffix = name.partition(":")
    try:
        factory = _REGISTRY[key]
    except KeyError:
        raise ValueError(
            f"unknown policy/discipline {name!r}; registered keys: "
            f"{sorted(_REGISTRY)}") from None
    if suffix:
        import inspect
        if "arg" not in inspect.signature(factory).parameters:
            raise ValueError(
                f"{key!r} takes no ':<arg>' suffix (got {name!r})")
        kwargs.setdefault("arg", suffix)
    return factory(**kwargs)


def make_discipline(obj: Union[str, ExecutionDiscipline, None]
                    ) -> ExecutionDiscipline:
    """Coerce strings/None into an :class:`ExecutionDiscipline`."""
    if obj is None:
        return StallingPrefill()
    out = make(obj)
    if not isinstance(out, ExecutionDiscipline):
        raise TypeError(f"{obj!r} is not an ExecutionDiscipline")
    return out


def _require(kwargs_value, what, key):
    if kwargs_value is None:
        raise ValueError(f"policy {key!r} needs {what}")
    return kwargs_value


@register("fcfs")
@register("priority")
def _make_fcfs(**_):
    return FCFSPolicy()


@register("planned")
def _make_planned(batches=None, **_):
    return PlannedPolicy(_require(batches, "batches=...", "planned"))


@register("slo-reanneal")
def _make_reanneal(model=None, max_batch=None, sa_params=None,
                   min_queue=2, backend=None, arg=None, **_):
    # "slo-reanneal:jax" selects the jitted annealer backend
    if backend is None:
        backend = arg if arg is not None else "python"
    return SLOReannealPolicy(_require(model, "model=...", "slo-reanneal"),
                             _require(max_batch, "max_batch=...",
                                      "slo-reanneal"),
                             sa_params, min_queue, backend=backend)


@register("slo-preempt")
def _make_preempt(model=None, margin=0.0, **_):
    return SLOPreemptPolicy(_require(model, "model=...", "slo-preempt"),
                            margin=margin)


@register("index")
@register("w-index")
def _make_index(model=None, mode=None, eps=1e-6, arg=None, **_):
    # "index:w" / "index:sjf" / "index:edf" select the family member;
    # "w-index" is shorthand for the default W-index
    if mode is None:
        mode = arg if arg is not None else "w"
    return IndexPolicy(_require(model, "model=...", "index"),
                       mode=mode, eps=eps)


@register("dynamic-chunk")
def _make_dynamic_chunk(model=None, base=None, min_chunk=16,
                        max_chunk=None, arg=None, **_):
    # "dynamic-chunk:128" caps the chunk at 128 tokens
    if max_chunk is None:
        max_chunk = int(arg) if arg is not None else 512
    return DynamicChunkPolicy(_require(model, "model=...", "dynamic-chunk"),
                              base=base, min_chunk=min_chunk,
                              max_chunk=max_chunk)


@register("stall")
def _make_stall(**_):
    return StallingPrefill()


@register("chunked")
def _make_chunked(arg=None, chunk_size=None, **_):
    if arg is not None:
        size = int(arg)
    else:
        size = chunk_size if chunk_size is not None else 64
    return ChunkedPrefill(size)


@register("route")
def _make_route(arg=None, model=None, max_batch=8, sa_params=None,
                memory=None, use_jax=True, **_):
    """Instance mappers: ``route:least-loaded`` (default),
    ``route:round-robin``, ``route:slo-affinity``,
    ``route:memory-greedy``, ``route:annealed`` (Algorithm 2; needs
    ``model=``)."""
    kind = arg or "least-loaded"
    if kind == "round-robin":
        return RoundRobinMapper()
    if kind == "least-loaded":
        return LeastLoadedMapper()
    if kind == "slo-affinity":
        return SLOAffinityMapper()
    if kind == "memory-greedy":
        return MemoryGreedyMapper(memory)
    if kind == "annealed":
        return AnnealedMapper(_require(model, "model=...", "route:annealed"),
                              max_batch=max_batch, sa_params=sa_params,
                              memory=memory, use_jax=use_jax)
    raise ValueError(f"unknown instance mapper 'route:{kind}'")
