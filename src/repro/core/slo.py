"""Request / SLO data model (paper §3.1, Eqs. 5 and 7).

Two streaming task classes:
  * ``h = 1`` — e2e-latency SLO (e.g. code completion: "a code is useful
    only when completed").
  * ``h = 0`` — interactivity SLO: TTFT and TPOT (e.g. chatbots).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class SLO:
    """All times in seconds. Unused fields are None ('/' in the paper)."""
    e2e: Optional[float] = None
    ttft: Optional[float] = None
    tpot: Optional[float] = None

    @property
    def h(self) -> int:
        """Eq. 5: 1 if the task prioritizes e2e latency."""
        return 1 if self.e2e is not None else 0


@dataclasses.dataclass
class Request:
    req_id: int
    task_type: str                 # e.g. "code", "chat"
    input_len: int
    slo: SLO
    # actual output length (known post-hoc; used by the simulator)
    output_len: Optional[int] = None
    # predicted output length (filled by the output-length predictor)
    predicted_output_len: Optional[int] = None
    arrival_time: float = 0.0
    # stamped by the executor (event core or engine) on *its* clock when
    # the request is submitted; SLO-budget shifting uses this so waited
    # time is never computed across two different clocks
    submit_time: Optional[float] = None
    prompt: Optional[object] = None   # raw payload for engine-backed runs
    # prompt tokens whose KV is already cached (shared-prefix reuse):
    # prefill computes only input_len - cached_prefix tokens, while
    # decode still attends the full context — every pricing layer
    # (objective, latency model, policies, event core) discounts prefill
    # by this, so cached-prefix requests rank by their true cost
    cached_prefix: int = 0

    @property
    def h(self) -> int:
        return self.slo.h

    def planning_output_len(self) -> int:
        if self.predicted_output_len is not None:
            return int(self.predicted_output_len)
        if self.output_len is not None:
            return int(self.output_len)
        raise ValueError(f"request {self.req_id} has no output length estimate")


def meets_slo(req: Request, t_e2e: float, t_ttft: float,
              t_tpot: float) -> bool:
    """Eq. 7: the x_i flag."""
    if req.h == 1:
        return t_e2e <= req.slo.e2e
    ok = True
    if req.slo.ttft is not None:
        ok &= t_ttft <= req.slo.ttft
    if req.slo.tpot is not None:
        ok &= t_tpot <= req.slo.tpot
    return bool(ok)


def as_arrays(requests) -> dict:
    """Columnar view used by the vectorized objective/annealer."""
    n = len(requests)
    big = 1e18
    return {
        "input_len": np.array([r.input_len for r in requests], np.float64),
        "output_len": np.array([r.planning_output_len() for r in requests],
                               np.float64),
        "h": np.array([r.h for r in requests], np.int32),
        "slo_e2e": np.array([r.slo.e2e if r.slo.e2e is not None else big
                             for r in requests], np.float64),
        "slo_ttft": np.array([r.slo.ttft if r.slo.ttft is not None else big
                              for r in requests], np.float64),
        "slo_tpot": np.array([r.slo.tpot if r.slo.tpot is not None else big
                              for r in requests], np.float64),
        # clipped below input_len: at least one prompt token is always
        # computed (prefill must produce true last-token logits)
        "cached_prefix": np.array(
            [min(max(int(getattr(r, "cached_prefix", 0) or 0), 0),
                 r.input_len - 1) for r in requests], np.float64),
    }
