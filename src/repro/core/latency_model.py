"""Latency predictor (paper §4.2, Eqs. 14–19).

Multiple linear regression with interaction terms, valid for lengths below
~2k tokens (the paper's stated fit region):

  prefill:          t_p(b, l_i)  = α_p·b·l_i + β_p·b + γ_p·l_i + δ_p
  per-token decode: τ_d(b, l_a)  = α_d·b·l_a + β_d·b + γ_d·l_a + δ_d

The decode total over l_o generated tokens (Eq. 16) has the closed form

  t_d(b, l_i, l_o) = Σ_{k=1..l_o} τ_d(b, l_i + k)
                   = (α_d·b + γ_d)·(l_i·l_o + l_o(l_o+1)/2) + (β_d·b + δ_d)·l_o

so schedule evaluation never loops over output tokens.

Coefficients are fit with ordinary least squares on profiler samples
(design matrix [b·l, b, l, 1]).  Units: seconds (the paper's Table 2 is in
milliseconds; we keep SI and convert at the fixture boundary).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class LinearLatencyModel:
    """Fitted coefficients for one LLM instance on one device type."""
    alpha_p: float
    beta_p: float
    gamma_p: float
    delta_p: float
    alpha_d: float
    beta_d: float
    gamma_d: float
    delta_d: float

    # ---------------- Eq. 14
    def prefill_time(self, b, l_i, cached=0):
        """``cached`` prompt tokens already in KV (shared-prefix reuse)
        are not computed: the prefill term is priced at the *unique*
        length ``l_i - cached``.  Decode terms keep the full context —
        cached pages are still attended."""
        l_i = l_i - cached
        return (self.alpha_p * b * l_i + self.beta_p * b
                + self.gamma_p * l_i + self.delta_p)

    # ---------------- Eq. 15
    def per_token_decode_time(self, b, l_a):
        return (self.alpha_d * b * l_a + self.beta_d * b
                + self.gamma_d * l_a + self.delta_d)

    # ---------------- Eq. 16 (closed form)
    def decode_time(self, b, l_i, l_o):
        tri = l_i * l_o + l_o * (l_o + 1) / 2.0
        return ((self.alpha_d * b + self.gamma_d) * tri
                + (self.beta_d * b + self.delta_d) * l_o)

    # ---------------- Eqs. 17, 18, 19
    def exec_time(self, b, l_i, l_o, cached=0):
        return self.prefill_time(b, l_i, cached) \
            + self.decode_time(b, l_i, l_o)

    def ttft_exec(self, b, l_i, cached=0):
        return self.prefill_time(b, l_i, cached)

    def tpot(self, b, l_i, l_o):
        l_o = np.maximum(l_o, 1)
        return self.decode_time(b, l_i, l_o) / l_o

    def as_tuple(self) -> Tuple[float, ...]:
        return dataclasses.astuple(self)

    def perturbed(self, rel: float, which: str = "all",
                  rng: np.random.Generator | None = None):
        """Scale coefficients by (1+rel) — used by the Fig.10 study."""
        vals = dataclasses.asdict(self)
        for k in list(vals):
            if which == "all" or k.startswith(which):
                vals[k] = vals[k] * (1.0 + rel)
        return LinearLatencyModel(**vals)


def _ols(samples: Sequence[Tuple[float, float, float]], nonneg: bool = False):
    """samples: (b, l, t). Returns (alpha, beta, gamma, delta).

    With ``nonneg`` the fit is constrained to non-negative coefficients
    by backward elimination: refit without the most negative column
    until none remain.  Unconstrained OLS on a handful of noisy
    wall-clock samples can balance a large positive term against a
    large negative one — fine inside the sampled range, but the
    extrapolated cost can go *negative*, which runs an event-driven
    simulator clock backwards.  Elimination keeps the surviving terms
    least-squares-calibrated instead of naively truncating them.
    """
    arr = np.asarray(samples, np.float64)
    b, l, t = arr[:, 0], arr[:, 1], arr[:, 2]
    X = np.stack([b * l, b, l, np.ones_like(b)], axis=1)
    keep = list(range(X.shape[1]))
    while True:
        coef, *_ = np.linalg.lstsq(X[:, keep], t, rcond=None)
        if not nonneg or len(coef) == 0 or float(coef.min()) >= 0.0:
            break
        keep.pop(int(np.argmin(coef)))
    full = np.zeros(X.shape[1])
    full[keep] = coef
    return tuple(full)


def fit(prefill_samples, decode_samples,
        nonneg: bool = False) -> LinearLatencyModel:
    """prefill_samples: (b, l_i, t_prefill); decode_samples: (b, l_a, τ_d)."""
    ap, bp, gp, dp = _ols(prefill_samples, nonneg=nonneg)
    ad, bd, gd, dd = _ols(decode_samples, nonneg=nonneg)
    return LinearLatencyModel(ap, bp, gp, dp, ad, bd, gd, dd)


# Paper Table 2 (V100 ×2, Qwen2.5-7B), converted ms → s.  Used as a golden
# fixture in tests and as a fallback before the local profiler has data.
PAPER_TABLE2 = LinearLatencyModel(
    alpha_p=0.1e-3, beta_p=5.7e-3, gamma_p=0.01e-3, delta_p=43.67e-3,
    alpha_d=0.0002e-3, beta_d=0.275e-3, gamma_d=0.00088e-3, delta_d=15.85e-3,
)
