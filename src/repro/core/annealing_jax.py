"""Jittable simulated-annealing priority mapper (beyond-paper).

The paper runs Algorithm 1 in Python on the host.  Here the whole anneal is
a single ``jax.lax`` program: the schedule lives in fixed-shape arrays, the
objective G is evaluated with segment ops, the temperature loop is a
``lax.while_loop`` and per-temperature iterations a ``lax.fori_loop``.
``vmap`` over PRNG keys yields independent tempering chains whose best
solution is taken — on TPU hosts this amortizes scheduler overhead across
chains and keeps it off the Python critical path.

Schedule representation (fixed N):
  perm [N] int32  — request index per priority position
  bnd  [N] bool   — batch boundary *before* each position (bnd[0] = True)

Moves mirror Algorithm 1: shift a boundary right (squeeze into previous
iteration), shift left / open a new one (delay into next iteration), swap
two positions.  Proposals violating the max-batch constraint are no-ops.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class JaxSAConfig:
    T0: float = 500.0
    T_thres: float = 20.0
    iters: int = 100
    tau: float = 0.95
    num_chains: int = 8


def _eval_g(li, lo, h, slo_e2e, slo_ttft, slo_tpot, coefs, perm, bnd):
    """Vectorized Eq. 2 objective. coefs: [8] latency-model params."""
    ap, bp, gp, dp, ad, bd, gd, dd = [coefs[i] for i in range(8)]
    n = li.shape[0]
    li, lo = li[perm], lo[perm]
    h = h[perm]
    s_e, s_t, s_p = slo_e2e[perm], slo_ttft[perm], slo_tpot[perm]

    batch_id = jnp.cumsum(bnd.astype(jnp.int32)) - 1          # [N]
    bsz = jnp.bincount(batch_id, length=n).astype(li.dtype)
    b_of = bsz[batch_id]

    t_pref = ap * b_of * li + bp * b_of + gp * li + dp
    tri = li * lo + lo * (lo + 1) / 2.0
    t_dec = (ad * b_of + gd) * tri + (bd * b_of + dd) * lo
    t_exec = t_pref + t_dec
    t_tpot = t_dec / jnp.maximum(lo, 1.0)

    bdur = jax.ops.segment_max(t_exec, batch_id, num_segments=n)
    bdur = jnp.where(bsz > 0, bdur, 0.0)
    wait_b = jnp.concatenate([jnp.zeros((1,), bdur.dtype),
                              jnp.cumsum(bdur)[:-1]])
    t_wait = wait_b[batch_id]
    e2e = t_exec + t_wait
    ttft = t_pref + t_wait
    met = jnp.where(h == 1, e2e <= s_e, (ttft <= s_t) & (t_tpot <= s_p))
    return jnp.sum(met) / jnp.maximum(jnp.sum(e2e), 1e-12)


def _propose(key, perm, bnd, max_batch):
    n = perm.shape[0]
    kop, k1, k2 = jax.random.split(key, 3)
    op = jax.random.randint(kop, (), 0, 3)
    i = jax.random.randint(k1, (), 1, n)          # position 1..n-1
    j = jax.random.randint(k2, (), 0, n)

    def sizes_ok(b):
        bid = jnp.cumsum(b.astype(jnp.int32)) - 1
        return jnp.all(jnp.bincount(bid, length=n) <= max_batch)

    def do_squeeze(_):
        # clear boundary at i, set at i+1 (if any): first elem of the batch
        # starting at i joins the previous iteration.
        valid = bnd[i]
        nb = bnd.at[i].set(False)
        nb = jax.lax.cond(i + 1 < n,
                          lambda b: b.at[jnp.minimum(i + 1, n - 1)].set(True),
                          lambda b: b, nb)
        ok = valid & sizes_ok(nb)
        return perm, jnp.where(ok, nb, bnd)

    def do_delay(_):
        # set boundary at i where none exists: the tail of the current batch
        # becomes / joins the next iteration.
        valid = ~bnd[i]
        nb = bnd.at[i].set(True)
        ok = valid & sizes_ok(nb)
        return perm, jnp.where(ok, nb, bnd)

    def do_swap(_):
        pi, pj = perm[i], perm[j]
        np_ = perm.at[i].set(pj).at[j].set(pi)
        return np_, bnd

    return jax.lax.switch(op, [do_squeeze, do_delay, do_swap], None)


@partial(jax.jit, static_argnames=("max_batch", "cfg"))
def anneal_chain(key, arrays, coefs, max_batch: int, cfg: JaxSAConfig):
    """One SA chain. arrays: tuple (li, lo, h, slo_e2e, slo_ttft, slo_tpot)."""
    li, lo, h, s_e, s_t, s_p = arrays
    n = li.shape[0]
    ev = partial(_eval_g, li, lo, h, s_e, s_t, s_p, coefs)

    # start 1: sorted by predicted e2e at max batch size
    t0 = (coefs[0] * max_batch * li + coefs[1] * max_batch + coefs[2] * li
          + coefs[3])
    tri = li * lo + lo * (lo + 1) / 2.0
    t0 = t0 + (coefs[4] * max_batch + coefs[6]) * tri \
        + (coefs[5] * max_batch + coefs[7]) * lo
    perm_s = jnp.argsort(t0).astype(jnp.int32)
    bnd0 = (jnp.arange(n) % max_batch) == 0
    f_s = ev(perm_s, bnd0)
    # start 2: arrival order
    perm_a = jnp.arange(n, dtype=jnp.int32)
    f_a = ev(perm_a, bnd0)
    perm = jnp.where(f_s >= f_a, perm_s, perm_a)
    f = jnp.maximum(f_s, f_a)
    f_ref = jnp.maximum(f, 1e-12)

    def temp_cond(state):
        T = state[0]
        return T >= cfg.T_thres

    def temp_body(state):
        T, key, perm, bnd, f, best_perm, best_bnd, best_f = state

        def it_body(_, inner):
            key, perm, bnd, f, bp, bb, bf = inner
            key, kp, ka = jax.random.split(key, 3)
            perm_c, bnd_c = _propose(kp, perm, bnd, max_batch)
            f_new = ev(perm_c, bnd_c)
            p_acc = jnp.exp((f_new - f) / (f_ref * T / cfg.T0))
            accept = (f_new > f) | (jax.random.uniform(ka) < p_acc)
            perm = jnp.where(accept, perm_c, perm)
            bnd = jnp.where(accept, bnd_c, bnd)
            f = jnp.where(accept, f_new, f)
            better = f > bf
            bp = jnp.where(better, perm, bp)
            bb = jnp.where(better, bnd, bb)
            bf = jnp.where(better, f, bf)
            return key, perm, bnd, f, bp, bb, bf

        key, perm, bnd, f, best_perm, best_bnd, best_f = jax.lax.fori_loop(
            0, cfg.iters, it_body,
            (key, perm, bnd, f, best_perm, best_bnd, best_f))
        return (T * cfg.tau, key, perm, bnd, f,
                best_perm, best_bnd, best_f)

    state = (jnp.float64(cfg.T0) if jax.config.read("jax_enable_x64")
             else jnp.float32(cfg.T0),
             key, perm, bnd0, f, perm, bnd0, f)
    state = jax.lax.while_loop(temp_cond, temp_body, state)
    _, _, _, _, _, best_perm, best_bnd, best_f = state
    return best_perm, best_bnd, best_f


def priority_mapping_jax(arrays_np: dict, model, max_batch: int,
                         cfg: JaxSAConfig = JaxSAConfig(), seed: int = 0):
    """vmapped parallel-tempering front end. Returns (perm, batch_id, G)."""
    arrs = tuple(jnp.asarray(arrays_np[k], jnp.float32) for k in
                 ("input_len", "output_len"))
    arrs += (jnp.asarray(arrays_np["h"], jnp.int32),)
    arrs += tuple(jnp.asarray(arrays_np[k], jnp.float32) for k in
                  ("slo_e2e", "slo_ttft", "slo_tpot"))
    coefs = jnp.asarray(model.as_tuple(), jnp.float32)
    keys = jax.random.split(jax.random.PRNGKey(seed), cfg.num_chains)
    perms, bnds, fs = jax.vmap(
        lambda k: anneal_chain(k, arrs, coefs, max_batch, cfg))(keys)
    best = int(jnp.argmax(fs))
    perm = np.asarray(perms[best])
    bnd = np.asarray(bnds[best])
    batch_id = np.cumsum(bnd.astype(np.int64)) - 1
    return perm.astype(np.int64), batch_id, float(fs[best])
