"""Jittable simulated-annealing priority mapper — batched and incremental.

The paper runs Algorithm 1 in Python on the host.  Here the whole anneal
is a single ``jax.lax`` program so Algorithm 2's per-instance mapping can
run as one jitted batch on the accelerator host: ``vmap`` over PRNG keys
yields independent tempering chains, and :func:`priority_mapping_multi_jax`
adds a second ``vmap`` over *instances* (padding ragged instance loads to
one fixed shape), which amortizes scheduler overhead across the fleet and
keeps it off the Python critical path.

Schedule representation (fixed N, with ``n_valid <= N`` real requests —
positions ``>= n_valid`` hold padding pinned as tail singletons that never
mix with real batches and are masked out of the objective):

  perm [N] int32  — request index per priority position
  bnd  [N] bool   — batch boundary *before* each position (bnd[0] = True)

Moves mirror Algorithm 1: shift a boundary right (squeeze into previous
iteration), shift left / open a new one (delay into next iteration), swap
two positions.  Proposals violating the max-batch constraint are no-ops.

Two scoring paths share one proposal stream:

* ``incremental=False`` — the oracle: every proposal re-evaluates the full
  Eq. 2 objective with segment ops over all N positions (:func:`_eval_g`).
* ``incremental=True`` (default) — the incremental-Δ fast path, the jitted
  port of ``objective.IncrementalEvaluator``.  The ``lax.while_loop``
  state carries per-batch segment aggregates, indexed by batch *start
  position*: the member SLO slacks **sorted ascending** (the largest
  batch wait under which each member still meets its SLO), the structural
  and valid-member sizes, Σ exec, and the batch duration.  A proposal
  rebuilds only the <= 3 touched rows (one vmapped O(max_batch) gather +
  sort over the precomputed linear-in-b request coefficients,
  ``objective.linear_request_coefs``) and scores the candidate without
  materializing it: the wait prefix cache is one ``cumsum`` over batch
  durations with the touched entries overridden, and each batch's met
  count is its valid-member count minus a batched ``searchsorted`` of its
  wait into the sorted slack row (lowered as a fused compare-reduce —
  the same rank).  The *logical* work is the Python evaluator's
  O(batch + n_batches·log b); under fixed jit shapes the scoring is a
  vectorized O(N·max_batch) compare-reduce plus O(N) prefix ops, so the
  win over the full objective is constant-factor and flat in N — every
  N-wide gather, sort, bincount and segment scatter leaves the
  per-proposal path (~3-6x at N >= 128 on CPU, see bench_overhead).
  Accepted rows are committed (and rejected rows reverted) by sparse
  scatters, so the hot loop never pays an O(N) select.

Both paths are cross-checked against the numpy ``objective.evaluate``
oracle (to 1e-6 under x64 — see tests/test_annealing_jax.py and
docs/annealer.md for the contract).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.objective import linear_request_coefs

# Column layout of the per-request coefficient matrix ``reqc`` [N, 11]:
# linear-in-batch-size latency terms (shared contract with the Python
# IncrementalEvaluator via objective.linear_request_coefs), the SLO class
# h, the three SLO budgets, and the padding mask.
_EA, _EC, _PA, _PC, _TA, _TC, _H, _SE, _ST, _SP, _VALID = range(11)
_NCOLS = 11


@dataclasses.dataclass(frozen=True)
class JaxSAConfig:
    """Anneal hyper-parameters (validated — invalid values used to turn
    every proposal into a silent no-op instead of failing loudly)."""
    T0: float = 500.0
    T_thres: float = 20.0
    iters: int = 100
    tau: float = 0.95
    num_chains: int = 8

    def __post_init__(self):
        if self.num_chains < 1:
            raise ValueError(
                f"num_chains must be >= 1, got {self.num_chains}")
        if self.iters < 1:
            raise ValueError(f"iters must be >= 1, got {self.iters}")
        if self.T0 <= 0 or self.T_thres <= 0:
            raise ValueError(
                f"temperatures must be positive, got T0={self.T0}, "
                f"T_thres={self.T_thres}")
        if self.T_thres > self.T0:
            raise ValueError(
                f"T_thres must be <= T0 (the anneal would run zero "
                f"proposals), got T0={self.T0}, T_thres={self.T_thres}")
        if not 0.0 < self.tau < 1.0:
            raise ValueError(f"tau must be in (0, 1), got {self.tau}")

    @property
    def n_levels(self) -> int:
        """Temperature levels under the schedule (>= 1 by validation)."""
        levels, T = 0, self.T0
        while T >= self.T_thres:
            levels += 1
            T *= self.tau
        return levels


def config_from_sa_params(params, num_chains: int = 8) -> JaxSAConfig:
    """Map a Python-annealer ``SAParams`` onto the jitted annealer.

    ``iters`` needs care: the jitted loop always runs ``iters`` proposals
    per temperature level (the Python ``budget_mode="per_level"``),
    whereas the Python default ``budget_mode="global"`` treats ``iters``
    as the TOTAL proposal budget.  A naive copy would inflate a global
    budget by the level count (~63x under the default schedule), so a
    global budget is spread across the levels instead.  ``moves`` and
    ``acceptance`` ablation knobs have no jitted counterpart (the JAX
    path always uses the full move set with Metropolis acceptance) and
    are rejected rather than silently dropped.
    """
    if tuple(params.moves) != (0, 1, 2) or params.acceptance != "metropolis":
        raise ValueError(
            "the JAX annealer supports only moves=(0, 1, 2) with "
            f"acceptance='metropolis'; got moves={params.moves!r}, "
            f"acceptance={params.acceptance!r} — use the Python backend "
            "for ablation configs")
    cfg = JaxSAConfig(T0=params.T0, T_thres=params.T_thres, iters=1,
                      tau=params.tau, num_chains=num_chains)
    if params.budget_mode == "global":
        iters = max(1, -(-params.iters // cfg.n_levels))      # ceil div
    else:
        iters = params.iters
    return dataclasses.replace(cfg, iters=iters)


# --------------------------------------------------------------- packing
def _pad_len(n: int) -> int:
    """Bucket N to the next power of two (>= 8) so online re-annealing at
    shifting queue depths reuses a handful of jit compilations instead of
    one per depth."""
    return max(8, 1 << max(int(n) - 1, 0).bit_length())


def _pack(arrays_np: dict, model, pad_to: int) -> jnp.ndarray:
    """Build the padded per-request coefficient matrix [pad_to, 11].

    Built in float64 and converted by ``jnp.asarray`` so the dtype follows
    the x64 flag (f32 by default, f64 under ``jax.experimental.enable_x64``
    for oracle-parity tests).  Padding rows are all-zero with VALID = 0:
    zero exec/prefill coefficients keep them out of batch durations and
    the latency sum, and the mask keeps them out of the met count.
    """
    n = len(arrays_np["input_len"])
    coefs = linear_request_coefs(arrays_np, model)
    cols = np.zeros((pad_to, _NCOLS), np.float64)
    for c, k in ((_EA, "eA"), (_EC, "eC"), (_PA, "pA"), (_PC, "pC"),
                 (_TA, "tA"), (_TC, "tC")):
        cols[:n, c] = coefs[k]
    cols[:n, _H] = np.asarray(arrays_np["h"], np.float64)
    cols[:n, _SE] = np.asarray(arrays_np["slo_e2e"], np.float64)
    cols[:n, _ST] = np.asarray(arrays_np["slo_ttft"], np.float64)
    cols[:n, _SP] = np.asarray(arrays_np["slo_tpot"], np.float64)
    cols[:n, _VALID] = 1.0
    return jnp.asarray(cols)


# ------------------------------------------------------------- objective
def _eval_g(reqc, perm, bnd):
    """Full Eq. 2 objective with segment ops over all N positions — the
    in-jit oracle the incremental path is checked against.  Returns
    ``(G, n_met)``; padding (VALID = 0) is excluded from both the met
    count and the latency denominator."""
    n = perm.shape[0]
    r = reqc[perm]
    batch_id = jnp.cumsum(bnd.astype(jnp.int32)) - 1          # [N]
    bsz = jnp.bincount(batch_id, length=n).astype(r.dtype)
    b_of = bsz[batch_id]

    t_exec = r[:, _EA] * b_of + r[:, _EC]
    t_pref = r[:, _PA] * b_of + r[:, _PC]
    t_tpot = r[:, _TA] * b_of + r[:, _TC]

    bdur = jax.ops.segment_max(t_exec, batch_id, num_segments=n)
    bdur = jnp.where(bsz > 0, bdur, 0.0)
    wait_b = jnp.concatenate([jnp.zeros((1,), bdur.dtype),
                              jnp.cumsum(bdur)[:-1]])
    t_wait = wait_b[batch_id]
    e2e = t_exec + t_wait
    ttft = t_pref + t_wait
    met = jnp.where(r[:, _H] == 1, e2e <= r[:, _SE],
                    (ttft <= r[:, _ST]) & (t_tpot <= r[:, _SP]))
    valid = r[:, _VALID] > 0
    n_met = jnp.sum((met & valid).astype(r.dtype))
    total = jnp.sum(jnp.where(valid, e2e, 0.0))
    return n_met / jnp.maximum(total, 1e-12), n_met


# ----------------------------------------------- incremental batch stats
# ``stats`` is a 5-tuple of arrays, row p describing the batch *starting
# at position p* (neutral everywhere else):
#   slacks [N, mb] — member SLO slacks sorted ascending, +inf padding
#   bsz    [N]     — structural batch size (incl. padding members)
#   cnt    [N]     — valid-member count (met/latency accounting)
#   sume   [N]     — sum of member exec times
#   bdur   [N]     — batch duration (max member exec)
def _row(reqc, perm_pad, start, size, mb: int):
    """Segment aggregates for a batch of ``size`` members at positions
    ``start .. start+size-1``.  ``perm_pad`` is perm padded with mb
    sentinels so the fixed-size window never clamps.  A member's *slack*
    is the largest batch wait under which it still meets its SLO:

      h = 1:  slack = slo_e2e  - exec(size)
      h = 0:  slack = slo_ttft - prefill(size)   if TPOT ok at this size,
              else -inf (can never be met)

    Non-members and padding get +inf (sorted last, never counted met).
    ``size == 0`` yields the neutral row."""
    idx = jax.lax.dynamic_slice(perm_pad, (start,), (mb,))
    r = reqc[idx]                                             # [mb, 11]
    memb = jnp.arange(mb) < size
    b = size.astype(r.dtype)
    ex = jnp.where(memb, r[:, _EA] * b + r[:, _EC], 0.0)
    sum_exec = jnp.sum(ex)
    bdur = jnp.where(size > 0,
                     jnp.max(jnp.where(memb, ex, -jnp.inf)), 0.0)
    pref = r[:, _PA] * b + r[:, _PC]
    tpot_ok = r[:, _TA] * b + r[:, _TC] <= r[:, _SP]
    slack = jnp.where(r[:, _H] == 1, r[:, _SE] - ex,
                      jnp.where(tpot_ok, r[:, _ST] - pref, -jnp.inf))
    live = memb & (r[:, _VALID] > 0)
    slack = jnp.where(live, slack, jnp.inf)
    cnt = jnp.sum(live.astype(r.dtype))
    return jnp.sort(slack), b, cnt, sum_exec, bdur


def _build_stats(reqc, perm, bnd, mb: int):
    """Vectorized O(N·mb) stats build for a whole schedule (used once per
    start; the anneal hot loop only rebuilds touched rows)."""
    n = perm.shape[0]
    pos = jnp.arange(n)
    batch_id = jnp.cumsum(bnd.astype(jnp.int32)) - 1
    sizes = jnp.bincount(batch_id, length=n)[batch_id]        # [N]
    perm_pad = jnp.concatenate([perm, jnp.zeros((mb,), perm.dtype)])
    slacks, bsz, cnt, sume, bdur = jax.vmap(
        lambda p, s: _row(reqc, perm_pad, p, s, mb))(pos, sizes)
    z = jnp.zeros((), reqc.dtype)
    return (jnp.where(bnd[:, None], slacks, jnp.inf),
            jnp.where(bnd, bsz, z), jnp.where(bnd, cnt, z),
            jnp.where(bnd, sume, z), jnp.where(bnd, bdur, z))


def _count_below(slack_rows, w):
    """Per-row count of slacks strictly below the row's wait — a batched
    ``searchsorted(row, w, side="left")`` into the sorted slack segments.
    For the mb-wide rows a masked compare-reduce computes the same rank
    in one fused kernel, which beats a vmapped binary search on CPU; the
    sorted order still matters (it is what makes the count a rank and
    keeps the Python/JAX backends' data structures interchangeable)."""
    return jnp.sum(slack_rows < w[..., None], axis=-1)


def _wait_prefix(bdur):
    """Exclusive prefix sums of batch durations — batch waits (Eq. 11)."""
    return jnp.concatenate([jnp.zeros((1,), bdur.dtype),
                            jnp.cumsum(bdur)[:-1]])


def _agg(stats, mb: int):
    """Score a schedule from its batch-stat rows alone:
    O(n_batches · log max_batch), no N-wide gathers."""
    slacks, bsz, cnt, sume, bdur = stats
    w = _wait_prefix(bdur)
    below = _count_below(slacks, w)
    n_met = jnp.sum(cnt - below.astype(cnt.dtype))
    total = jnp.sum(sume) + jnp.dot(cnt, w)
    return n_met / jnp.maximum(total, 1e-12), n_met


def _agg_delta(stats, sidx, rows, mb: int):
    """Score a candidate whose only changes vs the committed ``stats``
    are the 3 rebuilt rows ``rows`` at ``sidx`` — without materializing
    the candidate.  The wait prefix cache and the met/latency sums are
    recomputed over the [N] per-batch arrays with the touched entries
    overridden; untouched batches keep their sorted slack segments and
    only see a shifted wait."""
    slacks, bsz, cnt, sume, bdur = stats
    r_sl, r_b, r_cnt, r_se, r_bd = rows
    bdur_c = bdur.at[sidx].set(r_bd)
    cnt_c = cnt.at[sidx].set(r_cnt)
    sume_c = sume.at[sidx].set(r_se)
    w = _wait_prefix(bdur_c)
    below = _count_below(slacks, w).at[sidx].set(_count_below(r_sl, w[sidx]))
    n_met = jnp.sum(cnt_c - below.astype(cnt_c.dtype))
    total = jnp.sum(sume_c) + jnp.dot(cnt_c, w)
    return n_met / jnp.maximum(total, 1e-12), n_met


# ----------------------------------------------------------------- moves
def _sample_move(key, n_valid):
    """One (op, i, j) proposal plus the acceptance uniform, from a single
    4-draw so PRNG traffic stays off the hot path.  The same stream
    drives both scoring paths."""
    key, sub = jax.random.split(key)
    u = jax.random.uniform(sub, (4,))
    op = jnp.minimum((u[0] * 3).astype(jnp.int32), 2)
    hi = jnp.maximum(n_valid, 2)
    i = jnp.minimum(1 + (u[1] * (hi - 1).astype(u.dtype)).astype(jnp.int32),
                    hi - 1)
    lo_n = jnp.maximum(n_valid, 1)
    j = jnp.minimum((u[2] * lo_n.astype(u.dtype)).astype(jnp.int32),
                    lo_n - 1)
    return key, op, i, j, u[3]


def _start_of(bnd, i):
    """Start position of the batch containing position ``i``
    (bnd[0] is invariantly True, so the result is always >= 0)."""
    pos = jnp.arange(bnd.shape[0])
    return jnp.max(jnp.where(bnd & (pos <= i), pos, -1))


def _move_descriptors(perm, bnd, op, i, j, n_valid, mb: int):
    """Branch-free squeeze/delay/swap descriptor arithmetic shared by
    BOTH scoring paths, so their feasible move sets cannot diverge:
    the validity flag, the <= 2 perm entries a swap touches, and the
    <= 2 boundary bits a squeeze/delay touches (no-op writes of
    position 0 / the invariant bnd[0]=True otherwise).  Returns
    ``(ok, a_im1, i2, pidx, pval, bidx, bval)``."""
    n = perm.shape[0]
    is_sq = op == 0
    is_dl = op == 1
    is_sw = op == 2
    a_im1 = _start_of(bnd, i - 1)          # start of batch holding i-1
    i2 = jnp.minimum(i + 1, n - 1)
    # squeeze grows the previous batch (size i - a_im1 when bnd[i]) by
    # one; delay splits (never grows); swap only needs j in range
    ok = (i < n_valid) & jnp.where(
        is_sq, bnd[i] & (i - a_im1 < mb),
        jnp.where(is_dl, ~bnd[i], j < n_valid))
    z = jnp.zeros_like(i)
    pi, pj = perm[i], perm[j]
    pidx = jnp.where(is_sw, jnp.stack([i, j]), jnp.stack([z, z]))
    pval = jnp.where(is_sw, jnp.stack([pj, pi]),
                     jnp.stack([perm[0], perm[0]]))
    t_ = jnp.ones((), bool)
    bidx = jnp.where(is_sq, jnp.stack([i, i2]),
                     jnp.where(is_dl, jnp.stack([i, i]),
                               jnp.stack([z, z])))
    bval = jnp.where(is_sq, jnp.stack([jnp.zeros((), bool), i + 1 < n]),
                     jnp.stack([t_, t_]))
    return ok, a_im1, i2, pidx, pval, bidx, bval


def _candidate(reqc, perm, bnd, stats, op, i, j, n_valid, mb: int):
    """Move ``(op, i, j)`` as a branch-free sparse update.

    Every move is "rebuild <= 3 batch rows + <= 2 boundary bits +
    <= 2 perm entries", so instead of a ``lax.switch`` the descriptors
    (row start positions and new sizes) are selected arithmetically and
    all three rows are rebuilt by ONE vmapped :func:`_row` — far fewer
    ops inside the jitted loop.  Returns ``(ok, perm_c, upd)`` where
    ``perm_c`` is the candidate permutation (needed to build the rows)
    and ``upd = (pidx, pval, bidx, bval, sidx, rows)`` are the sparse
    updates; ``ok=False`` candidates carry garbage rows and must not be
    committed (:func:`_apply` with ``accept=False`` is a no-op)."""
    _, bsz, _, _, _ = stats
    is_sq = op == 0
    is_dl = op == 1
    ok, a_im1, i2, pidx, pval, bidx, bval = _move_descriptors(
        perm, bnd, op, i, j, n_valid, mb)
    a_i = jnp.where(bnd[i], i, a_im1)      # start of batch holding i
    a_j = _start_of(bnd, j)
    s_prev = bsz[a_im1].astype(jnp.int32)
    s_cur = bsz[i].astype(jnp.int32)
    s_old = bsz[a_i].astype(jnp.int32)
    s_j = bsz[a_j].astype(jnp.int32)
    left = i - a_i

    # squeeze: the batch starting at i loses its first member to the
    # previous batch; survivors re-start at i+1.  Rebuilding the (i+1)
    # row with its *current* size is a no-op when the squeezed batch was
    # a singleton followed by another batch, and yields the neutral row
    # (size 0) when i was the last position.
    sq3 = jnp.where(s_cur > 1, s_cur - 1,
                    jnp.where(i2 == i, 0, bsz[i2].astype(jnp.int32)))
    starts = jnp.where(
        is_sq, jnp.stack([a_im1, i, i2]),
        jnp.where(is_dl, jnp.stack([a_i, i, i]),
                  jnp.stack([a_i, a_j, a_j])))
    sizes = jnp.where(
        is_sq, jnp.stack([s_prev + 1, 0, sq3]),
        jnp.where(is_dl, jnp.stack([left, s_old - left, s_old - left]),
                  jnp.stack([s_old, s_j, s_j])))

    perm_c = perm.at[pidx].set(pval)
    perm_pad = jnp.concatenate([perm_c, jnp.zeros((mb,), perm.dtype)])
    rows = jax.vmap(lambda s, sz: _row(reqc, perm_pad, s, sz, mb))(
        starts, sizes)
    return ok, perm_c, (pidx, pval, bidx, bval, starts, rows)


def _apply(perm, bnd, stats, upd, accept):
    """Commit (``accept=True``) or discard a candidate's sparse updates —
    scatters only, never an O(N) select.  Duplicate indices in an update
    always carry identical values, so scatter order is immaterial."""
    pidx, pval, bidx, bval, sidx, rows = upd
    slacks, bsz, cnt, sume, bdur = stats
    r_sl, r_b, r_cnt, r_se, r_bd = rows
    sel = lambda new, cur: jnp.where(accept, new, cur)  # noqa: E731
    perm = perm.at[pidx].set(sel(pval, perm[pidx]))
    bnd = bnd.at[bidx].set(sel(bval, bnd[bidx]))
    stats = (slacks.at[sidx].set(sel(r_sl, slacks[sidx])),
             bsz.at[sidx].set(sel(r_b, bsz[sidx])),
             cnt.at[sidx].set(sel(r_cnt, cnt[sidx])),
             sume.at[sidx].set(sel(r_se, sume[sidx])),
             bdur.at[sidx].set(sel(r_bd, bdur[sidx])))
    return perm, bnd, stats


def _structural(perm, bnd, op, i, j, n_valid, mb: int):
    """Move application for the full-evaluate path (no stats carried) —
    the same :func:`_move_descriptors` arithmetic as the incremental
    path, applied densely, so both paths see one feasible move set by
    construction."""
    ok, _, _, pidx, pval, bidx, bval = _move_descriptors(
        perm, bnd, op, i, j, n_valid, mb)
    return ok, perm.at[pidx].set(pval), bnd.at[bidx].set(bval)


# ----------------------------------------------------------------- chains
def _starts(reqc, n_valid, mb: int):
    """The two Algorithm 1 starting solutions under padding: predicted-e2e
    order and arrival order, maximal batches over the real prefix, padding
    pinned as tail singletons."""
    n = reqc.shape[0]
    pos = jnp.arange(n)
    t0 = reqc[:, _EA] * mb + reqc[:, _EC]
    t0 = jnp.where(reqc[:, _VALID] > 0, t0, jnp.inf)
    perm_s = jnp.argsort(t0).astype(jnp.int32)                # stable
    perm_a = pos.astype(jnp.int32)
    bnd0 = ((pos % mb) == 0) | (pos >= n_valid)
    return perm_s, perm_a, bnd0


def anneal_chain(key, reqc, n_valid, max_batch: int, cfg: JaxSAConfig,
                 incremental: bool = True):
    """One SA chain over the padded instance.  Returns
    ``(best_perm, best_bnd, best_G)``.  Mirrors Algorithm 1 including the
    line-7 early exit: the temperature loop stops as soon as the best
    solution seen meets every (valid) SLO."""
    mb = max_batch
    f_dtype = reqc.dtype
    perm_s, perm_a, bnd0 = _starts(reqc, n_valid, mb)
    if incremental:
        stats_s = _build_stats(reqc, perm_s, bnd0, mb)
        stats_a = _build_stats(reqc, perm_a, bnd0, mb)
        f_s, met_s = _agg(stats_s, mb)
        f_a, met_a = _agg(stats_a, mb)
    else:
        f_s, met_s = _eval_g(reqc, perm_s, bnd0)
        f_a, met_a = _eval_g(reqc, perm_a, bnd0)
    pick = f_s >= f_a
    perm = jnp.where(pick, perm_s, perm_a)
    f = jnp.where(pick, f_s, f_a)
    met = jnp.where(pick, met_s, met_a)
    if incremental:
        stats = jax.tree_util.tree_map(
            lambda a, b: jnp.where(pick, a, b), stats_s, stats_a)
    else:
        stats = ()
    f_ref = jnp.maximum(f, 1e-12)
    n_valid_f = n_valid.astype(f_dtype)

    def temp_cond(state):
        T, *_, bmet = state
        return (T >= cfg.T_thres) & (bmet < n_valid_f)

    def temp_body(state):
        T = state[0]

        def it_body(_, inner):
            key, perm, bnd, stats, f, met, bp, bb, bf, bmet = inner
            key, op, i, j, u_acc = _sample_move(key, n_valid)
            if incremental:
                ok, perm_c, upd = _candidate(reqc, perm, bnd, stats, op,
                                             i, j, n_valid, mb)
                f_new, met_new = _agg_delta(stats, upd[4], upd[5], mb)
            else:
                ok, perm_c, bnd_c = _structural(perm, bnd, op, i, j,
                                                n_valid, mb)
                f_new, met_new = _eval_g(reqc, perm_c, bnd_c)
            p_acc = jnp.exp((f_new - f) / (f_ref * T / cfg.T0))
            accept = ok & ((f_new > f) | (u_acc < p_acc))
            if incremental:
                perm, bnd, stats = _apply(perm, bnd, stats, upd, accept)
            else:
                perm = jnp.where(accept, perm_c, perm)
                bnd = jnp.where(accept, bnd_c, bnd)
            f = jnp.where(accept, f_new, f)
            met = jnp.where(accept, met_new, met)
            better = f > bf
            bp = jnp.where(better, perm, bp)
            bb = jnp.where(better, bnd, bb)
            bf = jnp.where(better, f, bf)
            bmet = jnp.where(better, met, bmet)
            return key, perm, bnd, stats, f, met, bp, bb, bf, bmet

        inner = jax.lax.fori_loop(0, cfg.iters, it_body, state[1:])
        return (T * cfg.tau,) + inner

    T0 = jnp.asarray(cfg.T0, f_dtype)
    state = (T0, key, perm, bnd0, stats, f, met, perm, bnd0, f, met)
    state = jax.lax.while_loop(temp_cond, temp_body, state)
    _, _, _, _, _, _, _, best_perm, best_bnd, best_f, _ = state
    return best_perm, best_bnd, best_f


@partial(jax.jit, static_argnames=("max_batch", "cfg", "incremental"))
def _run_chains(keys, reqc, n_valid, max_batch: int, cfg: JaxSAConfig,
                incremental: bool):
    return jax.vmap(
        lambda k: anneal_chain(k, reqc, n_valid, max_batch, cfg,
                               incremental))(keys)


@partial(jax.jit, static_argnames=("max_batch", "cfg", "incremental"))
def _run_chains_multi(keys, reqcs, n_valids, max_batch: int,
                      cfg: JaxSAConfig, incremental: bool):
    """instances × chains in one jitted program: the outer vmap batches
    Algorithm 2's per-instance mapping, the inner one the tempering
    chains."""
    return jax.vmap(
        lambda ks, rc, nv: jax.vmap(
            lambda k: anneal_chain(k, rc, nv, max_batch, cfg,
                                   incremental))(ks))(keys, reqcs, n_valids)


# -------------------------------------------------------------- frontends
def _validate(max_batch: int, cfg: JaxSAConfig):
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    if not isinstance(cfg, JaxSAConfig):
        raise TypeError(f"cfg must be a JaxSAConfig, got {type(cfg)}")


def _extract(perm_pad, bnd_pad, n: int):
    perm = np.asarray(perm_pad)[:n]
    bnd = np.asarray(bnd_pad)[:n]
    batch_id = np.cumsum(bnd.astype(np.int64)) - 1
    return perm.astype(np.int64), batch_id


def priority_mapping_jax(arrays_np: dict, model, max_batch: int,
                         cfg: Optional[JaxSAConfig] = None, seed: int = 0,
                         incremental: bool = True):
    """vmapped parallel-tempering front end.  Returns
    ``(perm, batch_id, G)`` for the best chain.

    ``incremental=True`` (default) scores proposals with the jitted
    incremental-Δ evaluator; ``incremental=False`` re-evaluates the full
    objective per proposal (the oracle path, kept for cross-checking and
    benchmarking — see docs/annealer.md).
    """
    cfg = JaxSAConfig() if cfg is None else cfg
    _validate(max_batch, cfg)
    n = len(arrays_np["input_len"])
    if n == 0:
        return (np.zeros(0, np.int64), np.zeros(0, np.int64), 0.0)
    reqc = _pack(arrays_np, model, _pad_len(n))
    keys = jax.random.split(jax.random.PRNGKey(seed), cfg.num_chains)
    perms, bnds, fs = _run_chains(keys, reqc, jnp.int32(n), max_batch,
                                  cfg, incremental)
    best = int(jnp.argmax(fs))
    perm, batch_id = _extract(perms[best], bnds[best], n)
    return perm, batch_id, float(fs[best])


def priority_mapping_multi_jax(arrays_list: Sequence[dict], model,
                               max_batch: int,
                               cfg: Optional[JaxSAConfig] = None,
                               seed: int = 0, incremental: bool = True
                               ) -> List[Tuple[np.ndarray, np.ndarray,
                                               float]]:
    """Batch Algorithm 2's per-instance priority mapping as ONE jitted
    program: instances × chains, ragged instance loads padded to a common
    power-of-two length and masked out of the objective.

    ``arrays_list`` holds one columnar request view (``slo.as_arrays``)
    per instance; returns a ``(perm, batch_id, G)`` triple per instance,
    trimmed back to its real length.  Instance ``i`` anneals with PRNG
    key ``fold_in(PRNGKey(seed), i)`` so fleets are reproducible and
    instances stay independent.
    """
    cfg = JaxSAConfig() if cfg is None else cfg
    _validate(max_batch, cfg)
    sizes = [len(a["input_len"]) for a in arrays_list]
    if not sizes:
        return []
    pad = _pad_len(max(max(sizes), 1))
    reqcs = jnp.stack([_pack(a, model, pad) for a in arrays_list])
    n_valids = jnp.asarray(sizes, jnp.int32)
    base = jax.random.PRNGKey(seed)
    keys = jnp.stack([
        jax.random.split(jax.random.fold_in(base, i), cfg.num_chains)
        for i in range(len(sizes))])
    perms, bnds, fs = _run_chains_multi(keys, reqcs, n_valids, max_batch,
                                        cfg, incremental)
    out = []
    for i, n in enumerate(sizes):
        if n == 0:
            out.append((np.zeros(0, np.int64), np.zeros(0, np.int64), 0.0))
            continue
        best = int(jnp.argmax(fs[i]))
        perm, batch_id = _extract(perms[i, best], bnds[i, best], n)
        out.append((perm, batch_id, float(fs[i, best])))
    return out
