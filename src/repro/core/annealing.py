"""Algorithm 1 — simulated-annealing priority mapping (Python reference).

The working representation is ``batches: list[list[request_index]]`` —
contiguous priority order with explicit batch boundaries.  Three move
types (paper §4.3):

  0. squeezeLastIter — move a request into the *previous* batch iteration
     (valid when it is not in the first iteration and the previous batch has
     space).
  1. delayNextIter — move a request into the *next* batch iteration (valid
     when the next batch has space; delaying from the final batch opens a
     new iteration).
  2. randSwapping — exchange the positions of two requests.

Moves are *descriptors* (``("squeeze", k, j)`` etc.) scored by
``objective.IncrementalEvaluator`` in O(touched batch + n_batches) —
squeeze/delay/swap only perturb one or two batches, so the hot loop never
re-evaluates all N requests (``SAParams.incremental=False`` restores the
full-``evaluate``-per-proposal oracle path, kept for cross-checking and
benchmarking).  This is what keeps re-annealing cheap enough to run at
every admission event (paper Table 1's sub-millisecond overhead).

A jitted port of the same incremental-Δ data structures lives in
:mod:`repro.core.annealing_jax` — batched over tempering chains AND over
instances (Algorithm 2 as one vmapped program).  Both backends build
their per-batch slack segments from ``objective.linear_request_coefs``
and are cross-checked against the ``objective.evaluate`` oracle; see
docs/annealer.md for the shared contract and when each backend wins.

Acceptance: the paper's pseudocode line 32 (`exp(-(f_new-f)/T) < rand`)
as literally printed never accepts a worse solution (the exponent is
positive, so exp(·) > 1 > rand).  That degenerates to greedy descent and
contradicts the paper's own discussion of escaping local optima, so we
implement standard Metropolis acceptance on the *relative* objective delta,

    P(accept worse) = exp( (f_new - f) / (f_ref · T / T0) ),

which at T = T0 accepts a −10% move with p ≈ 0.9 and at T = T_thres
(20/500) with p ≈ 0.08 — matching the qualitative behaviour in Fig. 8.
``acceptance="greedy"`` reproduces the literal pseudocode.

Early exits (paper line 7, symmetric on both starts and mid-anneal): the
annealer returns as soon as the e2e-sorted start or the FCFS start meets
*all* SLOs, and mid-anneal as soon as an accepted candidate meets all
SLOs *and* is the best-G solution seen so far (the G guard preserves the
invariant that the result never scores below either starting solution —
an all-met schedule with pathologically long total latency is still a
worse G, the paper's actual objective).
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import List, Optional, Tuple

import numpy as np

from repro.core.latency_model import LinearLatencyModel
from repro.core.objective import (IncrementalEvaluator, evaluate,
                                  fcfs_schedule, sorted_by_e2e_schedule)


@dataclasses.dataclass
class SAParams:
    T0: float = 500.0
    T_thres: float = 20.0
    iters: int = 100          # iteration budget (see budget_mode)
    tau: float = 0.95         # decay rate
    acceptance: str = "metropolis"   # or "greedy" (paper pseudocode literal)
    # "global": Algorithm 1 as printed — k is initialized once (line 5) and
    # never reset, so ``iters`` bounds the TOTAL inner iterations across all
    # temperature levels (one extra eval per level after exhaustion, as the
    # repeat/until runs at least once).  This matches Table 1's near-constant
    # sub-millisecond overhead.  "per_level": k resets each level —
    # iters × n_levels evaluations (richer search, used for Fig. 8 sweeps).
    budget_mode: str = "global"
    # enabled move types (ablation studies): 0=squeeze, 1=delay, 2=swap
    moves: tuple = (0, 1, 2)
    seed: int = 0
    # score proposals with the incremental-Δ evaluator (False: full
    # ``evaluate`` per proposal — the O(N) oracle path)
    incremental: bool = True


@dataclasses.dataclass
class SAResult:
    perm: np.ndarray
    batch_id: np.ndarray
    G: float
    evaluations: int
    early_exit: bool
    history: Optional[list] = None


def _to_batches(perm, batch_id) -> List[List[int]]:
    nb = int(batch_id[-1]) + 1 if len(perm) else 0
    out = [[] for _ in range(nb)]
    for p, b in zip(perm, batch_id):
        out[b].append(int(p))
    return out


def _to_arrays(batches) -> Tuple[np.ndarray, np.ndarray]:
    perm, bid = [], []
    b_eff = 0
    for batch in batches:
        if not batch:
            continue
        perm.extend(batch)
        bid.extend([b_eff] * len(batch))
        b_eff += 1
    return np.array(perm, np.int64), np.array(bid, np.int64)


def _locate(batches: List[List[int]], flat: int) -> Tuple[int, int]:
    for bi, b in enumerate(batches):
        if flat < len(b):
            return bi, flat
        flat -= len(b)
    raise IndexError(flat)


def propose_move(batches: List[List[int]], max_batch: int,
                 rng: random.Random,
                 moves: tuple = (0, 1, 2),
                 n: Optional[int] = None) -> Optional[tuple]:
    """Sample a move descriptor; None if the sampled move is invalid
    (a no-op round, as in the paper's rejection of infeasible moves).
    ``n`` (total request count) may be passed to skip recounting."""
    nb = len(batches)
    if nb == 0:
        return None
    op = rng.choice(moves)
    if op == 0:        # squeezeLastIter: batch k -> k-1
        k = rng.randrange(nb)
        if k == 0 or len(batches[k - 1]) >= max_batch:
            return None
        return ("squeeze", k, rng.randrange(len(batches[k])))
    if op == 1:        # delayNextIter: batch k -> k+1 (maybe new)
        k = rng.randrange(nb)
        if len(batches[k]) == 1 and k == nb - 1:
            return None
        if k < nb - 1 and len(batches[k + 1]) >= max_batch:
            return None
        return ("delay", k, rng.randrange(len(batches[k])))
    # randSwapping: two distinct flat positions
    if n is None:
        n = sum(len(b) for b in batches)
    if n < 2:
        return None
    i1 = rng.randrange(n)
    i2 = rng.randrange(n - 1)
    if i2 >= i1:
        i2 += 1
    if nb == n:        # every batch is a singleton (e.g. max_batch == 1)
        return ("swap", i1, 0, i2, 0)
    b1, p1 = _locate(batches, i1)
    b2, p2 = _locate(batches, i2)
    return ("swap", b1, p1, b2, p2)


def apply_move(batches: List[List[int]], move: tuple) -> List[List[int]]:
    """Pure structural application of a move descriptor (new lists; the
    input is never mutated).  Mirror of ``IncrementalEvaluator.preview`` —
    used by the oracle path and the agreement tests."""
    new = list(batches)
    op = move[0]
    if op == "squeeze":
        k, j = move[1], move[2]
        src = new[k]
        new[k - 1] = new[k - 1] + [src[j]]
        rem = src[:j] + src[j + 1:]
        if rem:
            new[k] = rem
        else:
            del new[k]
    elif op == "delay":
        k, j = move[1], move[2]
        src = new[k]
        item = src[j]
        rem = src[:j] + src[j + 1:]
        if k == len(new) - 1:
            if rem:
                new[k] = rem
                new.append([item])
            else:      # singleton last batch: structurally a no-op
                new[k] = [item]
        else:
            new[k + 1] = [item] + new[k + 1]
            if rem:
                new[k] = rem
            else:
                del new[k]
    elif op == "swap":
        b1, i1, b2, i2 = move[1], move[2], move[3], move[4]
        if b1 == b2:
            nl = list(new[b1])
            nl[i1], nl[i2] = nl[i2], nl[i1]
            new[b1] = nl
        else:
            l1, l2 = list(new[b1]), list(new[b2])
            l1[i1], l2[i2] = l2[i2], l1[i1]
            new[b1], new[b2] = l1, l2
    else:
        raise ValueError(f"unknown move {move!r}")
    return new


def priority_mapping(arrays: dict, model: LinearLatencyModel,
                     max_batch: int, params: Optional[SAParams] = None,
                     record_history: bool = False) -> SAResult:
    """Algorithm 1.  arrays: columnar requests (slo.as_arrays)."""
    if params is None:       # None sentinel: a fresh SAParams per call
        params = SAParams()
    n = len(arrays["input_len"])
    rng = random.Random(params.seed)
    evals = 0

    # two starting solutions (lines 3, 12-15), each with the line-7 exit
    perm_s, bid_s = sorted_by_e2e_schedule(arrays, model, max_batch)
    ev_s = evaluate(arrays, model, perm_s, bid_s)
    evals += 1
    if ev_s.n_met == n:
        return SAResult(perm_s, bid_s, ev_s.G, evals, True,
                        [] if record_history else None)
    perm_0, bid_0 = fcfs_schedule(n, max_batch)
    ev_0 = evaluate(arrays, model, perm_0, bid_0)
    evals += 1
    if ev_0.n_met == n:
        return SAResult(perm_0, bid_0, ev_0.G, evals, True,
                        [] if record_history else None)
    if ev_s.G >= ev_0.G:
        batches = _to_batches(perm_s, bid_s)
    else:
        batches = _to_batches(perm_0, bid_0)

    inc = IncrementalEvaluator(arrays, model, batches) \
        if params.incremental else None
    f = inc.G if inc is not None else max(ev_s.G, ev_0.G)
    best_batches, best_f = batches, f
    f_ref = max(f, 1e-12)
    T = params.T0
    history = [] if record_history else None
    early = False
    k = 0                                    # line 5 — NOT reset per level
    while T >= params.T_thres:
        if params.budget_mode == "per_level":
            k = 0
        level_iters = max(params.iters - k, 1)   # repeat..until runs >= once
        for _ in range(level_iters):
            k += 1
            move = propose_move(batches, max_batch, rng, params.moves, n)
            if move is None:
                continue
            if inc is not None:
                f_new, n_met_new, staged = inc.preview(move)
            else:
                staged = apply_move(batches, move)
                perm_c, bid_c = _to_arrays(staged)
                ev_c = evaluate(arrays, model, perm_c, bid_c)
                f_new, n_met_new = ev_c.G, ev_c.n_met
            evals += 1
            accept = f_new > f
            if not accept and params.acceptance == "metropolis":
                p = math.exp((f_new - f) / (f_ref * T / params.T0))
                accept = rng.random() < p
            if accept:
                if inc is not None:
                    inc.commit(staged)
                    batches = inc.batches
                else:
                    batches = staged
                f = f_new
                if f > best_f:
                    best_batches, best_f = batches, f
                if n_met_new == n and f >= best_f:
                    # mid-anneal line-7 exit: all SLOs met — stop searching
                    best_batches, best_f = batches, f
                    early = True
                    break
        if early:
            break
        if history is not None:
            history.append((T, f, best_f))
        T *= params.tau
    perm_b, bid_b = _to_arrays(best_batches)
    # report G on the oracle scale (exact ``evaluate`` agreement)
    g_final = evaluate(arrays, model, perm_b, bid_b).G
    return SAResult(perm_b, bid_b, g_final, evals, early, history)
