"""Algorithm 1 — simulated-annealing priority mapping (Python reference).

The working representation is ``batches: list[list[request_index]]`` —
contiguous priority order with explicit batch boundaries.  Three move
types (paper §4.3):

  0. squeezeLastIter — move a request into the *previous* batch iteration
     (valid when it is not in the first iteration and the previous batch has
     space).
  1. delayNextIter — move a request into the *next* batch iteration (valid
     when the next batch has space; delaying from the final batch opens a
     new iteration).
  2. randSwapping — exchange the positions of two requests.

Acceptance: the paper's pseudocode line 32 (`exp(-(f_new-f)/T) < rand`)
as literally printed never accepts a worse solution (the exponent is
positive, so exp(·) > 1 > rand).  That degenerates to greedy descent and
contradicts the paper's own discussion of escaping local optima, so we
implement standard Metropolis acceptance on the *relative* objective delta,

    P(accept worse) = exp( (f_new - f) / (f_ref · T / T0) ),

which at T = T0 accepts a −10% move with p ≈ 0.9 and at T = T_thres
(20/500) with p ≈ 0.08 — matching the qualitative behaviour in Fig. 8.
``acceptance="greedy"`` reproduces the literal pseudocode.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import List, Optional, Tuple

import numpy as np

from repro.core.latency_model import LinearLatencyModel
from repro.core.objective import (evaluate, fcfs_schedule,
                                  sorted_by_e2e_schedule)


@dataclasses.dataclass
class SAParams:
    T0: float = 500.0
    T_thres: float = 20.0
    iters: int = 100          # iteration budget (see budget_mode)
    tau: float = 0.95         # decay rate
    acceptance: str = "metropolis"   # or "greedy" (paper pseudocode literal)
    # "global": Algorithm 1 as printed — k is initialized once (line 5) and
    # never reset, so ``iters`` bounds the TOTAL inner iterations across all
    # temperature levels (one extra eval per level after exhaustion, as the
    # repeat/until runs at least once).  This matches Table 1's near-constant
    # sub-millisecond overhead.  "per_level": k resets each level —
    # iters × n_levels evaluations (richer search, used for Fig. 8 sweeps).
    budget_mode: str = "global"
    # enabled move types (ablation studies): 0=squeeze, 1=delay, 2=swap
    moves: tuple = (0, 1, 2)
    seed: int = 0


@dataclasses.dataclass
class SAResult:
    perm: np.ndarray
    batch_id: np.ndarray
    G: float
    evaluations: int
    early_exit: bool
    history: Optional[list] = None


def _to_batches(perm, batch_id) -> List[List[int]]:
    nb = int(batch_id[-1]) + 1 if len(perm) else 0
    out = [[] for _ in range(nb)]
    for p, b in zip(perm, batch_id):
        out[b].append(int(p))
    return out


def _to_arrays(batches) -> Tuple[np.ndarray, np.ndarray]:
    perm, bid = [], []
    b_eff = 0
    for batch in batches:
        if not batch:
            continue
        perm.extend(batch)
        bid.extend([b_eff] * len(batch))
        b_eff += 1
    return np.array(perm, np.int64), np.array(bid, np.int64)


def _propose(batches: List[List[int]], max_batch: int,
             rng: random.Random,
             moves: tuple = (0, 1, 2)) -> Optional[List[List[int]]]:
    """Generate a neighbour; None if the sampled move is invalid (no-op)."""
    nb = len(batches)
    op = rng.choice(moves)
    new = [list(b) for b in batches]
    if op == 0:        # squeezeLastIter: batch k -> k-1
        k = rng.randrange(nb)
        if k == 0 or len(new[k - 1]) >= max_batch or not new[k]:
            return None
        j = rng.randrange(len(new[k]))
        new[k - 1].append(new[k].pop(j))
    elif op == 1:      # delayNextIter: batch k -> k+1 (maybe new)
        k = rng.randrange(nb)
        if not new[k] or len(new[k]) == 1 and k == nb - 1:
            return None
        if k == nb - 1:
            new.append([])
        if len(new[k + 1]) >= max_batch:
            return None
        j = rng.randrange(len(new[k]))
        new[k + 1].insert(0, new[k].pop(j))
    else:              # randSwapping
        flat = [(bi, i) for bi, b in enumerate(new) for i in range(len(b))]
        if len(flat) < 2:
            return None
        (b1, i1), (b2, i2) = rng.sample(flat, 2)
        new[b1][i1], new[b2][i2] = new[b2][i2], new[b1][i1]
    return [b for b in new if b]


def priority_mapping(arrays: dict, model: LinearLatencyModel,
                     max_batch: int, params: SAParams = SAParams(),
                     record_history: bool = False) -> SAResult:
    """Algorithm 1.  arrays: columnar requests (slo.as_arrays)."""
    n = len(arrays["input_len"])
    rng = random.Random(params.seed)
    evals = 0

    # two starting solutions (lines 3, 12-15)
    perm_s, bid_s = sorted_by_e2e_schedule(arrays, model, max_batch)
    ev_s = evaluate(arrays, model, perm_s, bid_s)
    evals += 1
    if ev_s.n_met == n:                      # line 7 early exit
        return SAResult(perm_s, bid_s, ev_s.G, evals, True,
                        [] if record_history else None)
    perm_0, bid_0 = fcfs_schedule(n, max_batch)
    ev_0 = evaluate(arrays, model, perm_0, bid_0)
    evals += 1
    if ev_s.G >= ev_0.G:
        batches, f = _to_batches(perm_s, bid_s), ev_s.G
    else:
        batches, f = _to_batches(perm_0, bid_0), ev_0.G

    best_batches, best_f = batches, f
    f_ref = max(f, 1e-12)
    T = params.T0
    history = [] if record_history else None
    k = 0                                    # line 5 — NOT reset per level
    while T >= params.T_thres:
        if params.budget_mode == "per_level":
            k = 0
        level_iters = max(params.iters - k, 1)   # repeat..until runs >= once
        for _ in range(level_iters):
            k += 1
            cand = _propose(batches, max_batch, rng, params.moves)
            if cand is None:
                continue
            perm_c, bid_c = _to_arrays(cand)
            f_new = evaluate(arrays, model, perm_c, bid_c).G
            evals += 1
            accept = f_new > f
            if not accept and params.acceptance == "metropolis":
                p = math.exp((f_new - f) / (f_ref * T / params.T0))
                accept = rng.random() < p
            if accept:
                batches, f = cand, f_new
                if f > best_f:
                    best_batches, best_f = batches, f
        if history is not None:
            history.append((T, f, best_f))
        T *= params.tau
    perm_b, bid_b = _to_arrays(best_batches)
    return SAResult(perm_b, bid_b, best_f, evals, False, history)
