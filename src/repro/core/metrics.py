"""Serving observability: latency percentiles, per-task SLO attainment,
and G over sliding windows — the counters an operator actually watches.

Consumes either engine result dicts ({req_id: {e2e, ttft, tpot, met}}) or
simulator ``SimResult``s; exports CSV rows compatible with the benchmark
harness format.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.simulator import SimResult
from repro.core.slo import Request


@dataclasses.dataclass
class ServingReport:
    count: int
    attainment: float
    G: float
    e2e_p50: float
    e2e_p90: float
    e2e_p99: float
    ttft_p50: float
    ttft_p90: float
    tpot_p50: float
    tpot_p90: float
    per_task: Dict[str, dict]

    def rows(self, prefix: str = "serving"):
        out = [[f"{prefix}_summary", 0.0,
                f"n={self.count};att={self.attainment:.3f};G={self.G:.4f};"
                f"e2e_p50={self.e2e_p50:.3f};e2e_p99={self.e2e_p99:.3f};"
                f"ttft_p90={self.ttft_p90:.3f};tpot_p90={self.tpot_p90:.4f}"]]
        for task, d in self.per_task.items():
            out.append([f"{prefix}_{task}", 0.0,
                        f"n={d['n']};att={d['att']:.3f};"
                        f"e2e_p90={d['e2e_p90']:.3f}"])
        return out


def _pct(xs, p):
    return float(np.percentile(np.asarray(xs), p)) if len(xs) else 0.0


def report(results, requests: Optional[Sequence[Request]] = None
           ) -> ServingReport:
    """results: SimResult or engine dict."""
    if isinstance(results, SimResult):
        e2e = results.e2e
        ttft = results.ttft
        tpot = results.tpot
        met = results.met
    else:
        e2e = {k: v["e2e"] for k, v in results.items()}
        ttft = {k: v["ttft"] for k, v in results.items()}
        tpot = {k: v["tpot"] for k, v in results.items()}
        met = {k: v["met"] for k, v in results.items()}
    n = len(e2e)
    total = sum(e2e.values())
    g = sum(met.values()) / total if total else 0.0
    per_task: Dict[str, dict] = {}
    if requests:
        by_task: Dict[str, List[int]] = {}
        for r in requests:
            by_task.setdefault(r.task_type, []).append(r.req_id)
        for task, ids in by_task.items():
            ids = [i for i in ids if i in e2e]
            per_task[task] = {
                "n": len(ids),
                "att": (sum(met[i] for i in ids) / len(ids)) if ids else 0.0,
                "e2e_p90": _pct([e2e[i] for i in ids], 90),
            }
    es, ts, ps = list(e2e.values()), list(ttft.values()), list(tpot.values())
    return ServingReport(
        count=n,
        attainment=sum(met.values()) / max(n, 1),
        G=g,
        e2e_p50=_pct(es, 50), e2e_p90=_pct(es, 90), e2e_p99=_pct(es, 99),
        ttft_p50=_pct(ts, 50), ttft_p90=_pct(ts, 90),
        tpot_p50=_pct(ps, 50), tpot_p90=_pct(ps, 90),
        per_task=per_task,
    )
