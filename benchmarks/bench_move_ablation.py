"""Beyond-paper ablation: contribution of each Algorithm-1 move type
(squeezeLastIter / delayNextIter / randSwapping) to the achieved G."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import PAPER_TABLE2, SAParams, as_arrays, priority_mapping
from repro.data.synthetic import sample_requests

SETS = {
    "all": (0, 1, 2),
    "no_squeeze": (1, 2),
    "no_delay": (0, 2),
    "no_swap": (0, 1),
    "swap_only": (2,),
}


def main(quick: bool = False):
    rows = []
    import dataclasses
    for n, mb in ((12, 2), (24, 4)) if not quick else ((12, 2),):
        reqs = sample_requests(n, seed=61 + n)
        for r in reqs:   # tighten SLOs to avoid the early exit
            r.slo = dataclasses.replace(
                r.slo,
                e2e=r.slo.e2e * 0.25 if r.slo.e2e else None,
                ttft=r.slo.ttft * 0.05 if r.slo.ttft else None,
                tpot=r.slo.tpot * 0.6 if r.slo.tpot else None)
            r.predicted_output_len = r.output_len
        arrays = as_arrays(reqs)
        for name, moves in SETS.items():
            gs = []
            for seed in (0, 1, 2):
                res = priority_mapping(
                    arrays, PAPER_TABLE2, mb,
                    SAParams(seed=seed, moves=moves,
                             budget_mode="per_level"))
                gs.append(res.G)
            rows.append([f"ablate_n{n}_b{mb}_{name}", 0.0,
                         f"G_best={max(gs):.5f};G_mean={np.mean(gs):.5f}"])
    emit(rows, ["name", "us_per_call", "derived"], "move_ablation")
    return rows


if __name__ == "__main__":
    main()
