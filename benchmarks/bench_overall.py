"""Paper Fig. 7 — overall performance: G, SLO attainment, average latency
for the SA SLO-aware scheduler vs FCFS (vLLM-like) and exhaustive search,
across request counts × max batch sizes.

Execution: the discrete-event simulator driven by the fitted latency model
(Table-2 coefficients by default) with the paper's SLOs; SA plans with
Gaussian-predicted output lengths while execution uses actual lengths —
the same prediction gap the paper's experiments have.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import (PAPER_TABLE2, SAParams, as_arrays, evaluate,
                        exhaustive_search, priority_mapping,
                        run_fcfs_continuous, run_priority_continuous)
from repro.core.profiler import OutputLengthPredictor
from repro.data.synthetic import sample_requests

MODEL = PAPER_TABLE2
EXHAUSTIVE_MAX = {1: 8, 2: 6, 4: 6}   # paper cuts exhaustive off here


def _planned_batches(reqs, res):
    nb = int(res.batch_id[-1]) + 1
    return [[reqs[i] for i, b in zip(res.perm, res.batch_id) if b == j]
            for j in range(nb)]


def run_case(n_req: int, max_batch: int, seed: int):
    reqs = sample_requests(n_req, seed=seed)
    # plan with predicted output lengths from a warmed output-length model
    pred = OutputLengthPredictor(seed=seed)
    for r in sample_requests(200, seed=seed + 999):
        pred.observe(r.task_type, r.output_len)
    for r in reqs:
        r.predicted_output_len = pred.predict(r.task_type)
    arrays = as_arrays(reqs)

    rows = {}
    # vLLM-like FCFS continuous batching (SLO-unaware)
    sim = run_fcfs_continuous(reqs, MODEL, max_batch)
    rows["fcfs"] = (sim.G, sim.attainment, sim.avg_latency, 0.0)

    # simulated-annealing SLO-aware
    # quality regime: per-level budget, scaled with n (paper §5.2 advises
    # scaling T0/iter with the search space; see EXPERIMENTS.md on the
    # overhead-vs-quality configuration discrepancy)
    res, dt = timeit(priority_mapping, arrays, MODEL, max_batch,
                     SAParams(seed=seed, budget_mode="per_level"),
                     repeat=1)
    sim = run_priority_continuous(_planned_batches(reqs, res), MODEL,
                                  max_batch)
    rows["sa"] = (sim.G, sim.attainment, sim.avg_latency, dt)

    # exhaustive (small cases only)
    if n_req <= EXHAUSTIVE_MAX.get(max_batch, 0):
        (perm, bid, g, _), dt = timeit(exhaustive_search, arrays, MODEL,
                                       max_batch, repeat=1)
        class _R:  # noqa: N801
            pass
        r = _R(); r.perm, r.batch_id = perm, bid
        sim = run_priority_continuous(_planned_batches(reqs, r), MODEL,
                                      max_batch)
        rows["exhaustive"] = (sim.G, sim.attainment, sim.avg_latency, dt)
    return rows


def main(quick: bool = False):
    rows = []
    req_counts = [4, 6, 8, 10] if quick else [4, 6, 8, 10, 20, 40]
    for max_batch in (1, 2, 4):
        for n in req_counts:
            case = run_case(n, max_batch, seed=100 + n + max_batch)
            base_g = case["fcfs"][0]
            for policy, (g, att, avg, dt) in case.items():
                rows.append([f"fig7_b{max_batch}_n{n}_{policy}",
                             round(dt * 1e6, 1),
                             f"G={g:.4f};att={att:.3f};avg={avg:.2f};"
                             f"G_vs_fcfs={g / base_g if base_g else 0:.3f}"])
    emit(rows, ["name", "us_per_call", "derived"], "fig7_overall")
    return rows


if __name__ == "__main__":
    main()
