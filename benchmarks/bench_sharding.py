"""Sharded-serving benchmark: tensor-parallel decode scaling and the
data-parallel fleet's attainment under overload.

Standalone on purpose (not part of ``benchmarks.run``): the first thing
this module does is force an 8-device CPU host
(``--xla_force_host_platform_device_count=8``), which is process-global
— running it in its own interpreter keeps every other suite on the
normal single-device path.

Row families (plus ``experiments/bench/BENCH_sharding.json``):

* ``tp{N}_decode`` — paged decode µs/token through a mesh-sharded
  engine at tp ∈ {1, 2, 4, 8} over the same prompts.  On this CPU
  container the XLA "devices" are host threads sharing the same cores,
  so µs/token does *not* drop with tp — the row's value is tracking
  the SPMD overhead (all-gathers, per-shard dispatch) and, on a real
  TPU host, becoming the scaling curve.  Token parity with the
  unsharded engine is asserted on every tp point.
* ``fleet{N}_...`` — single engine vs an N=2 :class:`EngineFleet` on
  the same Poisson trace at ~2x the single engine's measured
  saturation throughput.  The fleet must match-or-beat the single
  engine's wall-clock SLO attainment (asserted; this is the
  acceptance criterion for data-parallel serving actually helping).
"""
from __future__ import annotations

import os

# must precede any jax import in this process (device count is locked
# at backend init)
_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = \
        (os.environ.get("XLA_FLAGS", "") + f" {_FLAG}=8").strip()

import json
import time

import numpy as np

from benchmarks.common import RESULTS_DIR, emit
from repro.data.synthetic import sample_serve_workload


def _tiny_cfg():
    from repro.models import ModelConfig
    # 8 kv heads so every tp point in {1,2,4,8} head-shards evenly
    return ModelConfig(name="bench-tp", family="dense", num_layers=2,
                       d_model=128, num_heads=8, num_kv_heads=8,
                       head_dim=16, d_ff=256, vocab_size=97,
                       dtype="float32")


def _mesh(tp: int):
    import jax
    from jax.sharding import Mesh
    devs = np.array(jax.devices()[:tp]).reshape(1, tp)
    return Mesh(devs, ("data", "model"))


def _fill_slots(eng, n_prompt: int, budget: int, seed: int = 0):
    """Occupy every slot with a RUNNING request (prefill done)."""
    from repro.core.slo import SLO, Request
    from repro.engine.request import RuntimeRequest
    rng = np.random.default_rng(seed)
    rts = []
    for slot in range(eng.max_slots):
        toks = rng.integers(1, eng.cfg.vocab_size - 1, n_prompt)
        rt = RuntimeRequest(
            request=Request(req_id=slot, task_type="chat",
                            input_len=n_prompt, slo=SLO(),
                            output_len=budget),
            prompt_tokens=toks.astype(np.int32), max_new_tokens=budget)
        eng.begin_prefill(rt, slot)
        eng.prefill_step(rt)
        rts.append(rt)
    return rts


def _tp_rows(quick: bool):
    import jax

    from repro.engine.engine import Engine
    from repro.models import init_params

    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_dev = jax.local_device_count()
    tps = [t for t in (1, 2, 4, 8) if t <= n_dev]
    rounds = 8 if quick else 24
    slots = 4
    rows, payload = [], {}
    ref_tokens = None
    for tp in tps:
        eng = Engine(cfg, params, max_slots=slots, max_seq_len=256,
                     mesh=None if tp == 1 else _mesh(tp))
        rts = _fill_slots(eng, n_prompt=64, budget=rounds + 2)
        eng.decode_round()                      # warm + first token
        t0 = time.perf_counter()
        for _ in range(rounds):
            eng.decode_round()
        wall = time.perf_counter() - t0
        us_tok = wall / (rounds * slots) * 1e6
        toks = [list(rt.generated) for rt in rts]
        if ref_tokens is None:
            ref_tokens = toks
        assert toks == ref_tokens, f"tp={tp} decode tokens diverged"
        payload[f"tp{tp}"] = {"us_per_token": us_tok,
                              "devices": tp, "rounds": rounds,
                              "batch": slots, "token_parity": True}
        rows.append([f"tp{tp}_decode", round(us_tok, 2),
                     f"devices={tp};batch={slots};rounds={rounds};"
                     f"parity=1"])
    payload["local_devices"] = n_dev
    return rows, payload


def _trace(n, seed, rate, scale):
    return sample_serve_workload(n, 97, seed=seed, scale=scale,
                                 arrival_rate=rate, in_range=(8, 48),
                                 out_range=(4, 16))


def _fleet_rows(quick: bool):
    import jax

    from repro.engine.engine import Engine
    from repro.models import init_params
    from repro.serving import EngineFleet, ServeLoop

    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)

    def make_engine():
        return Engine(cfg, params, max_slots=4, max_seq_len=128)

    n_cal = 8 if quick else 16
    n = 16 if quick else 32
    scale = 0.5 if quick else 0.25

    # --- calibrate the single engine's saturation throughput: serve a
    # backlogged trace (all arrivals at t=0) and take req/s
    loop = ServeLoop(make_engine())
    cal = _trace(n_cal, seed=7, rate=0.0, scale=10.0)
    loop.start(warm_lengths=[len(p) for _, p in cal])
    loop.submit_trace(cal)
    t0 = time.perf_counter()
    loop.serve()
    sat_rate = n_cal / (time.perf_counter() - t0)
    rate = 2.0 * sat_rate

    def run(target):
        trace = _trace(n, seed=13, rate=rate, scale=scale)
        target.start(warm_lengths=[len(p) for _, p in trace])
        target.submit_trace(trace)
        target.serve()
        return target.metrics.summary()

    single = run(ServeLoop(make_engine()))
    fleet = run(EngineFleet([make_engine() for _ in range(2)],
                            mapper="least-loaded"))
    assert fleet["n"] == single["n"] == n
    assert fleet["attainment"] >= single["attainment"], (
        f"fleet attainment {fleet['attainment']:.3f} fell below the "
        f"single engine's {single['attainment']:.3f} at 2x saturation")
    rows = []
    for name, s in (("fleet1_single", single), ("fleet2_least_loaded",
                                                fleet)):
        rows.append([name, round(s["e2e_mean"] * 1e6, 1),
                     f"att={s['attainment']:.3f};G={s['G']:.4f};"
                     f"ttft_mean={s['ttft_mean'] * 1e3:.1f}ms;"
                     f"qdepth={s.get('queue_depth_mean', 0):.1f};"
                     f"tok_s={s['tokens_per_s']:.0f}"])
    payload = {"saturation_rps": sat_rate, "rate": rate, "n": n,
               "scale": scale, "single": single, "fleet2": fleet}
    return rows, payload


def main(quick: bool = False):
    rows, tp_payload = _tp_rows(quick)
    f_rows, f_payload = _fleet_rows(quick)
    rows.extend(f_rows)
    payload = {"tp_scaling": tp_payload, "fleet": f_payload}
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "BENCH_sharding.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# saved {path}")
    emit(rows, ["name", "us_per_call", "derived"], "sharding")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick)
