"""HLO inspection utility for the perf loop: list the largest collectives
(with source attribution via op metadata) for one (arch × shape × mesh).

  PYTHONPATH=src python -m benchmarks.hlo_inspect --arch phi4-mini-3.8b \
      --shape train_4k [--multi-pod] [--top 15]
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse    # noqa: E402
import re          # noqa: E402

from repro.launch import dryrun  # noqa: E402

_SHAPE_RE = dryrun._SHAPE_RE
_BYTES = dryrun._BYTES


def top_collectives(hlo: str, top: int = 15):
    rows = []
    for line in hlo.splitlines():
        s = line.strip()
        m = re.search(r"\s(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)(-start)?\(", s)
        if not m:
            continue
        lhs = s.split("=")[0] + "=" + s.split("=", 1)[1].split(m.group(1))[0]
        nbytes = 0
        shapes = []
        for sm in _SHAPE_RE.finditer(lhs):
            n = 1
            dims = sm.group(2)
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * _BYTES[sm.group(1)]
            shapes.append(f"{sm.group(1)}[{dims}]")
        meta = ""
        mm = re.search(r'op_name="([^"]+)"', s)
        if mm:
            meta = mm.group(1)
        rows.append((nbytes, m.group(1), ";".join(shapes[:2]), meta[:150]))
    rows.sort(reverse=True)
    return rows[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--expert-parallel", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--attn-auto", action="store_true",
                    help="sequence-parallel attention constraints")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache")
    args = ap.parse_args()
    from repro.distributed.sharding import ParallelismConfig
    from repro.launch.mesh import make_production_mesh
    cfg = dryrun.get_config(args.arch)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    par = None
    tp_gb = cfg.param_count() * 2 / 16 / 2**30
    par = ParallelismConfig(
        dp_axes=("pod", "data") if args.multi_pod else ("data",),
        fsdp=(not args.no_fsdp) and
             (dryrun.SHAPES[args.shape]["kind"] == "train" or tp_gb > 8),
        expert_parallel=args.expert_parallel,
        attn_sharding="auto" if args.attn_auto else "none")
    fn, a, in_sh, out_sh = dryrun.build_step(cfg, args.shape, mesh, par,
                                             kv_quant=args.kv_quant)
    import jax
    compiled = jax.jit(fn, in_shardings=in_sh,
                       out_shardings=out_sh).lower(*a).compile()
    hlo = compiled.as_text()
    print(f"== top collectives: {args.arch} × {args.shape} ==")
    total = 0
    for nbytes, kind, shape, meta in top_collectives(hlo, args.top):
        total += nbytes
        print(f"{nbytes / 2**20:10.1f} MiB  {kind:18s} {shape:34s} {meta}")
    coll, counts = dryrun.collective_bytes(hlo)
    print("totals MiB:", {k: round(v / 2**20, 1) for k, v in coll.items()
                          if v})
    rec = dryrun.analyze(compiled)
    print(f"flops/dev={rec['flops_per_device']:.4g} "
          f"peak={rec['peak_bytes'] / 2**30:.2f}GiB "
          f"args={rec['argument_bytes'] / 2**30:.2f}GiB "
          f"coll_total={sum(coll.values()) / 2**30:.2f}GiB")


if __name__ == "__main__":
    main()
