"""Shared benchmark utilities."""
from __future__ import annotations

import csv
import io
import os
import sys
import time

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "bench")


def emit(rows, header, name):
    """Print ``name,us_per_call,derived`` CSV rows + save the full table."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    print(f"# saved {path}")
    for r in rows:
        print(",".join(str(x) for x in r))


def timeit(fn, *args, repeat: int = 3, **kw):
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best
