"""Paper Fig. 11 — multi-instance scalability: G enhancement and scheduling
overhead for 1–4 instances (10 requests replicated per instance, as in the
paper's setup)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import (PAPER_TABLE2, SAParams, SLOAwareScheduler,
                        run_fcfs_continuous, run_priority_continuous)
from repro.core.profiler import MemoryModel
from repro.data.synthetic import sample_requests

MODEL = PAPER_TABLE2


def main(quick: bool = False):
    rows = []
    base_reqs = sample_requests(10, seed=21)
    for r in base_reqs:
        r.predicted_output_len = r.output_len
    for n_inst in (1, 2, 4) if quick else (1, 2, 3, 4):
        reqs = []
        rid = 0
        for copy in range(n_inst):
            for r in base_reqs:
                import dataclasses
                rr = dataclasses.replace(r, req_id=rid)
                reqs.append(rr)
                rid += 1
        sched = SLOAwareScheduler(
            MODEL, num_instances=n_inst, max_batch=4,
            memory=MemoryModel(total_memory=32e9, mu=0.9,
                               sigma_per_token=2e5),
            sa_params=SAParams(seed=9))   # paper-default budget
        t0 = time.perf_counter()
        out = sched.schedule(reqs)
        dt = time.perf_counter() - t0
        parts = [run_priority_continuous(q.batches, MODEL, 4)
                 for q in out.queues]
        met = sum(sum(p.met.values()) for p in parts)
        tot = sum(p.total_latency for p in parts)
        class _S:  # noqa: N801
            G = met / tot if tot else 0.0
        sim = _S()
        # FCFS baseline: same requests round-robin across instances
        base_g = 0.0
        fcfs_parts = [run_fcfs_continuous(reqs[i::n_inst], MODEL, 4)
                      for i in range(n_inst)]
        met = sum(sum(p.met.values()) for p in fcfs_parts)
        tot = sum(p.total_latency for p in fcfs_parts)
        base_g = met / tot if tot else 0.0
        rows.append([f"fig11_inst{n_inst}", round(dt * 1e6, 1),
                     f"G={sim.G:.4f};G_fcfs={base_g:.4f};"
                     f"enhancement={(sim.G - base_g) / base_g if base_g else 0:.3f};"
                     f"sched_ms={dt * 1e3:.2f}"])
    emit(rows, ["name", "us_per_call", "derived"], "fig11_scaling")
    return rows


if __name__ == "__main__":
    main()
