"""Kernel microbenchmarks: Pallas (interpret) correctness deltas vs oracle
and XLA-reference timings on CPU.  On real TPU hardware the same harness
times the compiled kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan


def main(quick: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)

    # flash attention
    B, S, H, KV, hd = 2, 256, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    r, t_ref = timeit(lambda: jax.block_until_ready(
        ref.flash_attention_ref(q, k, v)), repeat=2)
    o, t_pal = timeit(lambda: jax.block_until_ready(
        flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)),
        repeat=1)
    err = float(jnp.max(jnp.abs(o - r)))
    rows.append(["flash_attention_256", round(t_ref * 1e6, 1),
                 f"interpret_err={err:.2e}"])

    # decode attention
    L = 2048 if not quick else 512
    kc = jax.random.normal(ks[1], (B, L, KV, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, L, KV, hd), jnp.float32)
    qd = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    nv = jnp.array([L // 2, L], jnp.int32)
    r, t_ref = timeit(lambda: jax.block_until_ready(
        ref.decode_attention_ref(qd, kc, vc, nv)), repeat=2)
    o, _ = timeit(lambda: jax.block_until_ready(
        decode_attention(qd, kc, vc, nv, block_k=256, interpret=True)),
        repeat=1)
    err = float(jnp.max(jnp.abs(o - r)))
    rows.append([f"decode_attention_L{L}", round(t_ref * 1e6, 1),
                 f"interpret_err={err:.2e}"])

    # ssd scan
    b, s, nh, hdim, ds = 2, 256, 4, 64, 32
    x = jax.random.normal(ks[0], (b, s, nh, hdim), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bm = jax.random.normal(ks[3], (b, s, ds))
    Cm = jax.random.normal(ks[4], (b, s, ds))
    (yr, _), t_ref = timeit(lambda: jax.tree.map(
        jax.block_until_ready, ref.ssd_ref(x, dt, A, Bm, Cm, chunk=64)),
        repeat=2)
    (y, _), _ = timeit(lambda: jax.tree.map(
        jax.block_until_ready,
        ssd_scan(x, dt, A, Bm, Cm, chunk=64, interpret=True)), repeat=1)
    err = float(jnp.max(jnp.abs(y - yr)))
    rows.append([f"ssd_scan_{s}", round(t_ref * 1e6, 1),
                 f"interpret_err={err:.2e}"])
    emit(rows, ["name", "us_per_call", "derived"], "kernels")
    return rows


if __name__ == "__main__":
    main()
