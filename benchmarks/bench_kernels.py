"""Kernel microbenchmarks: Pallas (interpret) correctness deltas vs oracle
and XLA-reference timings on CPU.  On real TPU hardware the same harness
times the compiled kernels.

The ``paged_*`` rows are the paged-KV-pool acceptance metrics (also
written to ``experiments/bench/BENCH_paged.json`` for the perf
trajectory):

* ``paged_decode`` — µs/token at equal live tokens: dense decode over
  its worst-case-length slot vs paged decode gathering live pages only.
* ``paged_commit`` — per-prefill slot-commit cost as the pool grows:
  the dense layout's whole-slot ``.at[slot].set`` scatter is O(pool);
  the paged in-place page scatter (jit buffer donation) stays flat.
* ``paged_capacity`` — concurrent admissions at a fixed HBM budget on a
  short-prompt mix: the paged pool prices HBM by live tokens, the dense
  layout by ``max_slots × max_seq_len``.

The ``prefix_*`` rows are the shared-prefix KV reuse acceptance metrics
(written to ``experiments/bench/BENCH_prefix.json``): engine prefill
time/tokens vs prefix hit rate, and concurrent admissions at a 1 GiB KV
budget with 90 %-shared prompts vs the exclusive pool.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS_DIR, emit, timeit
from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan


def _paged_rows(quick: bool):
    """Paged-pool acceptance rows; returns (csv_rows, json_payload)."""
    from repro.kernels.decode_attention_paged import decode_attention_paged
    from repro.models import ModelConfig
    from repro.models.cache import kv_bytes_per_token

    rows, payload = [], {}
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    B, H, KV, hd, P = 4, 8, 2, 64, 16
    live = 256 if quick else 512            # live tokens per sequence
    Lmax = 2 * live                         # the dense slot's worst case
    npg = live // P
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, Lmax, KV, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, Lmax, KV, hd), jnp.float32)
    nv = jnp.full((B,), live, jnp.int32)
    null = jnp.zeros((1, P, KV, hd), jnp.float32)
    kp = jnp.concatenate([null, kc[:, :live].reshape(-1, P, KV, hd)], 0)
    vp = jnp.concatenate([null, vc[:, :live].reshape(-1, P, KV, hd)], 0)
    bt = jnp.arange(1, 1 + B * npg, dtype=jnp.int32).reshape(B, npg)

    # --- decode µs/token at equal live tokens (CPU: both XLA ref paths;
    # on TPU the same harness times the compiled Pallas kernels)
    fd = jax.jit(ref.decode_attention_ref)
    fp = jax.jit(ref.decode_attention_paged_ref)
    fd(q, kc, vc, nv).block_until_ready()
    fp(q, kp, vp, bt, nv).block_until_ready()
    _, td = timeit(lambda: fd(q, kc, vc, nv).block_until_ready(), repeat=5)
    _, tp = timeit(lambda: fp(q, kp, vp, bt, nv).block_until_ready(),
                   repeat=5)
    # correctness of the Pallas kernel on this exact shape
    err = float(jnp.max(jnp.abs(
        decode_attention_paged(q, kp, vp, bt, nv, interpret=True)
        - fp(q, kp, vp, bt, nv))))
    ratio = tp / td
    rows.append([f"paged_decode_live{live}", round(tp * 1e6, 1),
                 f"vs_dense_slot{Lmax}={ratio:.3f};interpret_err={err:.1e}"])
    payload["decode"] = {"live_tokens": live, "dense_slot_len": Lmax,
                         "dense_us": td * 1e6, "paged_us": tp * 1e6,
                         "paged_vs_dense": ratio, "interpret_err": err}

    # --- per-prefill slot-commit cost vs pool size
    S = 64                                  # committed prompt tokens
    one = jax.random.normal(ks[1], (S, KV, hd), jnp.float32)
    commit = {}
    for slots in ((4, 16) if quick else (8, 64)):
        # dense: whole-slot scatter into [slots, Lmax, KV, hd]
        dense_pool = jnp.zeros((slots, Lmax, KV, hd))
        slot_kv = jnp.zeros((Lmax, KV, hd)).at[:S].set(one)
        fdc = jax.jit(lambda p, o: p.at[0].set(o))
        fdc(dense_pool, slot_kv).block_until_ready()
        _, tdc = timeit(
            lambda: fdc(dense_pool, slot_kv).block_until_ready(), repeat=5)
        # paged: O(S) scatter into [slots*npages, P, KV, hd], donated
        npages = Lmax // P
        fpc = jax.jit(lambda p, o, pg, of: p.at[pg, of].set(o),
                      donate_argnums=0)
        pg = jnp.repeat(jnp.arange(1, 1 + S // P, dtype=jnp.int32), P)
        of = jnp.tile(jnp.arange(P, dtype=jnp.int32), S // P)
        paged_pool = jnp.zeros((1 + slots * npages, P, KV, hd))
        paged_pool = fpc(paged_pool, one, pg, of)       # warm (donates)
        def run():
            pool = jnp.zeros((1 + slots * npages, P, KV, hd))
            pool.block_until_ready()
            _, t = timeit(
                lambda: fpc(pool, one, pg, of).block_until_ready(),
                repeat=1)
            return t
        tpc = min(run() for _ in range(5))
        commit[slots] = {"dense_us": tdc * 1e6, "paged_us": tpc * 1e6}
        rows.append([f"paged_commit_slots{slots}", round(tpc * 1e6, 1),
                     f"dense_us={tdc * 1e6:.1f};"
                     f"paged_vs_dense={tpc / tdc:.4f}"])
    lo, hi = sorted(commit)
    payload["commit"] = {
        "tokens": S, "per_slots": commit,
        "paged_growth": commit[hi]["paged_us"] / commit[lo]["paged_us"],
        "dense_growth": commit[hi]["dense_us"] / commit[lo]["dense_us"]}
    rows.append(["paged_commit_growth",
                 round(payload["commit"]["paged_growth"], 3),
                 f"pool_x{hi // lo};"
                 f"dense_growth={payload['commit']['dense_growth']:.2f}"])

    # --- admission capacity at a fixed HBM budget (short-prompt mix)
    cfg = ModelConfig(name="cap", family="dense", num_layers=16,
                      d_model=2048, num_heads=16, num_kv_heads=4, d_ff=8192,
                      vocab_size=32000, dtype="bfloat16")
    bpt = kv_bytes_per_token(cfg)
    max_seq = 4096
    dense_slots = 8
    hbm = dense_slots * max_seq * bpt       # the dense engine's KV budget
    blocks = hbm // (P * bpt)
    rng = np.random.default_rng(0)
    admitted = 0
    free = int(blocks)
    while True:                             # short prompts + bounded output
        need = -(-int(rng.integers(64, 512) + 256) // P)
        if need > free:
            break
        free -= need
        admitted += 1
    rows.append(["paged_capacity", admitted,
                 f"dense_slots={dense_slots};hbm_gb={hbm / 2**30:.2f};"
                 f"capacity_x={admitted / dense_slots:.2f}"])
    payload["capacity"] = {"hbm_bytes": int(hbm),
                           "dense_concurrent": dense_slots,
                           "paged_concurrent": admitted,
                           "ratio": admitted / dense_slots}
    return rows, payload


def _prefix_rows(quick: bool):
    """Shared-prefix KV reuse acceptance rows; returns
    (csv_rows, json_payload):

    * ``prefix_prefill_hit*`` — engine prefill wall time and computed
      tokens vs prefix hit rate on a shared-system-prompt mix: at 90 %
      shared the engine prefills only the unique tail.
    * ``prefix_capacity_1gib`` — concurrent admissions at a 1 GiB KV
      budget with 90 %-shared prompts: refcounted aliasing vs the PR-5
      exclusive pool.
    """
    from repro.core.slo import SLO, Request
    from repro.engine.engine import Engine
    from repro.engine.request import RuntimeRequest
    from repro.models import ModelConfig, init_params
    from repro.models.cache import kv_bytes_per_token

    rows, payload = [], {}
    cfg = ModelConfig(name="bench-tiny", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=128, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    total = 160                             # prompt tokens per request
    n_req = 4 if quick else 8

    class _Rec:                             # prefill (tokens, seconds)
        def __init__(self):
            self.samples = []

        def observe_prefill(self, b, l, t):
            self.samples.append((int(l), float(t)))

        def observe_decode(self, b, l, t):
            pass

    def run(shared_frac):
        rng = np.random.default_rng(0)
        shared_len = (int(total * shared_frac) // 16) * 16
        head = rng.integers(0, 128, shared_len)
        rts = []
        for i in range(n_req):
            toks = np.concatenate(
                [head, rng.integers(0, 128, total - shared_len)]).astype(
                np.int32)
            rts.append(RuntimeRequest(
                request=Request(req_id=i, task_type="chat",
                                input_len=total,
                                slo=SLO(ttft=60.0, tpot=10.0)),
                prompt_tokens=toks, max_new_tokens=4))
        rec = _Rec()
        eng = Engine(cfg, params, max_slots=n_req, max_seq_len=512,
                     temperature=0.0, profiler=rec)
        eng.run_fcfs(rts)
        toks_done = sum(l for l, _ in rec.samples)
        t_pref = sum(t for _, t in rec.samples)
        return (toks_done, t_pref, eng.prefix_stats()["hit_rate"])

    base_toks, base_t, _ = run(0.0)
    payload["prefill"] = {"prompt_tokens": total, "requests": n_req,
                          "sweep": {}}
    for frac in (0.5, 0.9):
        toks_done, t_pref, hit = run(frac)
        payload["prefill"]["sweep"][str(frac)] = {
            "hit_rate": hit, "prefill_tokens": toks_done,
            "prefill_s": t_pref,
            "tokens_vs_unshared": toks_done / base_toks,
            "time_vs_unshared": t_pref / base_t if base_t else 0.0}
        rows.append([f"prefix_prefill_hit{int(frac * 100)}",
                     round(t_pref * 1e6, 1),
                     f"hit_rate={hit:.3f};"
                     f"tokens={toks_done}/{base_toks};"
                     f"time_vs_unshared={t_pref / base_t:.3f}"])

    # --- capacity at 1 GiB with 90% shared prefixes (host arithmetic,
    # production-scale config): exclusive pool vs refcounted aliasing
    big = ModelConfig(name="cap", family="dense", num_layers=16,
                      d_model=2048, num_heads=16, num_kv_heads=4,
                      d_ff=8192, vocab_size=32000, dtype="bfloat16")
    P = 16
    bpt = kv_bytes_per_token(big)
    blocks = (1 << 30) // (P * bpt)         # 1 GiB of KV pages
    prompt, out_budget = 2048, 256
    shared_blocks = (int(prompt * 0.9) // P)
    need_full = -(-(prompt + out_budget) // P)
    need_unique = need_full - shared_blocks
    excl = int(blocks // need_full)
    shared = 0
    free = int(blocks)
    while free >= (need_full if shared == 0 else need_unique):
        free -= need_full if shared == 0 else need_unique
        shared += 1
    rows.append(["prefix_capacity_1gib", shared,
                 f"exclusive={excl};shared_x={shared / max(excl, 1):.2f};"
                 f"prompt={prompt};shared_frac=0.9"])
    payload["capacity_1gib"] = {
        "blocks": int(blocks), "prompt_tokens": prompt,
        "output_budget": out_budget, "shared_frac": 0.9,
        "exclusive_concurrent": excl, "shared_concurrent": shared,
        "ratio": shared / max(excl, 1)}
    return rows, payload


def main(quick: bool = False):
    rows = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)

    # flash attention
    B, S, H, KV, hd = 2, 256, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    r, t_ref = timeit(lambda: jax.block_until_ready(
        ref.flash_attention_ref(q, k, v)), repeat=2)
    o, t_pal = timeit(lambda: jax.block_until_ready(
        flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)),
        repeat=1)
    err = float(jnp.max(jnp.abs(o - r)))
    rows.append(["flash_attention_256", round(t_ref * 1e6, 1),
                 f"interpret_err={err:.2e}"])

    # decode attention
    L = 2048 if not quick else 512
    kc = jax.random.normal(ks[1], (B, L, KV, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, L, KV, hd), jnp.float32)
    qd = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    nv = jnp.array([L // 2, L], jnp.int32)
    r, t_ref = timeit(lambda: jax.block_until_ready(
        ref.decode_attention_ref(qd, kc, vc, nv)), repeat=2)
    o, _ = timeit(lambda: jax.block_until_ready(
        decode_attention(qd, kc, vc, nv, block_k=256, interpret=True)),
        repeat=1)
    err = float(jnp.max(jnp.abs(o - r)))
    rows.append([f"decode_attention_L{L}", round(t_ref * 1e6, 1),
                 f"interpret_err={err:.2e}"])

    # ssd scan
    b, s, nh, hdim, ds = 2, 256, 4, 64, 32
    x = jax.random.normal(ks[0], (b, s, nh, hdim), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,)))
    Bm = jax.random.normal(ks[3], (b, s, ds))
    Cm = jax.random.normal(ks[4], (b, s, ds))
    (yr, _), t_ref = timeit(lambda: jax.tree.map(
        jax.block_until_ready, ref.ssd_ref(x, dt, A, Bm, Cm, chunk=64)),
        repeat=2)
    (y, _), _ = timeit(lambda: jax.tree.map(
        jax.block_until_ready,
        ssd_scan(x, dt, A, Bm, Cm, chunk=64, interpret=True)), repeat=1)
    err = float(jnp.max(jnp.abs(y - yr)))
    rows.append([f"ssd_scan_{s}", round(t_ref * 1e6, 1),
                 f"interpret_err={err:.2e}"])

    # paged KV pool: decode / slot-commit / capacity acceptance rows
    paged_rows, payload = _paged_rows(quick)
    rows.extend(paged_rows)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "BENCH_paged.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# saved {path}")

    # shared-prefix reuse: prefill-vs-hit-rate / 1 GiB capacity rows
    prefix_rows, prefix_payload = _prefix_rows(quick)
    rows.extend(prefix_rows)
    path = os.path.join(RESULTS_DIR, "BENCH_prefix.json")
    with open(path, "w") as f:
        json.dump(prefix_payload, f, indent=2)
    print(f"# saved {path}")

    emit(rows, ["name", "us_per_call", "derived"], "kernels")
    return rows


if __name__ == "__main__":
    main()
