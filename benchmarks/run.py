"""Benchmark harness — one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick]``
Prints ``name,us_per_call,derived`` CSV for every benchmark.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps (CI mode)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (bench_annealing_params, bench_fit,
                            bench_goodput, bench_kernels,
                            bench_latency_pred, bench_move_ablation,
                            bench_online, bench_output_pred,
                            bench_overall, bench_overhead, bench_scaling,
                            bench_serving)
    suites = {
        "fig7_overall": bench_overall.main,
        "table1_overhead": bench_overhead.main,
        "fig8_annealing_params": bench_annealing_params.main,
        "fig9_output_pred": bench_output_pred.main,
        "fig10_latency_pred": bench_latency_pred.main,
        "fig11_scaling": bench_scaling.main,
        "table2_fit": bench_fit.main,
        "kernels": bench_kernels.main,
        "move_ablation": bench_move_ablation.main,
        "online": bench_online.main,
        "serving": bench_serving.main,
        "goodput": bench_goodput.main,
    }
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            fn(quick=args.quick)
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
