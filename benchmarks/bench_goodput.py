"""Trace-replay goodput sweep: SLO attainment / goodput / mean latency
vs load for the registry policy zoo — the repo's paper-figure-shaped
artifact (the paper's headline attainment-vs-rate comparison, but
against a stronger field of competitors and across several model
architectures).

Workloads replay the checked-in dataset histograms
(``experiments/traces/*.json`` — Python-Code-23k-ShareGPT +
ShareGPT_Vicuna shapes with the paper's per-task SLOs) through the
unified event core (:func:`repro.core.events.simulate`) at thousands of
requests.  Each model config gets an *analytic* latency model scaled
from the paper's fitted Table 2 coefficients (Qwen2.5-7B on V100s):
compute-bound terms scale with the architecture's parameter count,
attention/KV-bound terms with its KV bytes per token.  The differential
conformance suite (``tests/test_conformance.py``) pins the event core
to the real engine, which is what makes these simulated curves
trustworthy at scales the CI engine cannot reach.

Load is swept as a fraction of each config's estimated saturation
throughput, so curves are comparable across architectures; the arrival
process is swept too (Poisson / bursty / diurnal) in the full run.

Outputs (``experiments/bench/``):
  * ``BENCH_goodput.json``        — per-(config, policy, process, load)
    summaries + the analytic models (fully deterministic: no wall times,
    guarded by the seeded-determinism regression test)
  * ``goodput_attainment.csv``    — the attainment-vs-load long table
    (one row per config × policy × process × load — the figure data)
  * ``goodput.csv`` via ``common.emit`` — trajectory rows (these carry
    wall-clock sim times and are *not* part of the deterministic
    artifact)
"""
from __future__ import annotations

import csv
import dataclasses
import json
import os

import numpy as np

from benchmarks.common import RESULTS_DIR, emit, timeit
from repro.configs import get_config
from repro.core import PAPER_TABLE2, LinearLatencyModel, SAParams, simulate
from repro.core.policies import make
from repro.data.traces import sample_trace
from repro.models.cache import kv_bytes_per_token

#: the sweep's architectures: the paper's evaluation model + a smaller
#: dense code model + a long-context GQA model with heavy KV traffic
#: + a non-dense entrant (MLA: latent-compressed KV makes its decode
#: terms scale by the ckv/kpe bytes, not full per-head KV)
CONFIGS = ("qwen2.5-7b", "starcoder2-3b", "phi4-mini-3.8b",
           "deepseek-v2-lite-16b")

#: every policy that draws a curve; quick mode keeps the acceptance
#: field (fcfs + both paper policies + the W-index entrant)
POLICIES = ("fcfs", "slo-reanneal", "slo-preempt",
            "index", "index:sjf", "index:edf", "dynamic-chunk")
QUICK_POLICIES = ("fcfs", "slo-reanneal", "slo-preempt", "index")

MAX_BATCH = 8


def analytic_model(cfg, base: LinearLatencyModel = PAPER_TABLE2,
                   ref=None) -> LinearLatencyModel:
    """Scale the paper's fitted coefficients to another architecture:
    compute-bound terms (prefill FLOPs, per-request decode compute,
    weight streaming) go with the parameter count; attention/KV-bound
    terms (the ``·l`` interactions) go with KV bytes per token."""
    ref = ref if ref is not None else get_config("qwen2.5-7b")
    s_p = cfg.param_count() / ref.param_count()
    s_kv = kv_bytes_per_token(cfg) / kv_bytes_per_token(ref)
    return LinearLatencyModel(
        alpha_p=base.alpha_p * s_p, beta_p=base.beta_p * s_p,
        gamma_p=base.gamma_p * s_kv, delta_p=base.delta_p,
        alpha_d=base.alpha_d * s_kv, beta_d=base.beta_d * s_p,
        gamma_d=base.gamma_d * s_kv, delta_d=base.delta_d * s_p)


def saturation_rps(model: LinearLatencyModel, med_in: int,
                   med_out: int, max_batch: int = MAX_BATCH) -> float:
    """Estimated saturation throughput (req/s): a full batch of median
    requests shares its decode rounds, so the pipeline completes
    ``max_batch`` requests per solo-prefill + batched-decode span."""
    t = model.prefill_time(1, med_in) \
        + model.decode_time(max_batch, med_in, med_out)
    return max_batch / t


def _median_lengths(seed: int = 0, n: int = 2000):
    probe = sample_trace(n, seed=seed)
    return (int(np.median([r.input_len for r in probe])),
            int(np.median([r.output_len for r in probe])))


def _run_one(cfg_name: str, model: LinearLatencyModel, policy: str,
             n: int, rate: float, process: str, seed: int):
    """One (config, policy, process, load) cell through the event core."""
    reqs = sample_trace(n, rate=rate, process=process, seed=seed)
    for r in reqs:
        r.predicted_output_len = r.output_len
    pol = make(policy, model=model, max_batch=MAX_BATCH,
               sa_params=SAParams(seed=0))
    # dynamic-chunk carries its own adaptive chunked discipline — that
    # is the policy; everyone else runs the stalling default
    disc = getattr(pol, "discipline", None)
    res, dt = timeit(simulate, reqs, model, MAX_BATCH, pol,
                     discipline=disc, respect_arrivals=True, repeat=1)
    ttfts = list(res.ttft.values())
    return {
        "attainment": round(res.attainment, 4),
        "goodput": round(res.G, 6),
        "mean_latency": round(res.avg_latency, 4),
        "mean_ttft": round(float(np.mean(ttfts)), 4) if ttfts else 0.0,
        "p90_ttft": round(float(np.percentile(ttfts, 90)), 4)
        if ttfts else 0.0,
        "preemptions": res.n_preempted,
        "n": res.n,
    }, dt


def sweep(configs=CONFIGS, policies=POLICIES, loads=(0.4, 0.8, 1.2, 1.6),
          processes=("poisson",), n: int = 2000, seed: int = 0):
    """The full sweep as a pure function of its arguments — returns
    ``(emit_rows, payload, curve_rows)``; everything except the
    ``us_per_call`` column of ``emit_rows`` is deterministic in
    ``seed`` (the determinism regression test relies on this)."""
    med_in, med_out = _median_lengths(seed=seed)
    payload = {"meta": {"n": n, "seed": seed, "max_batch": MAX_BATCH,
                        "median_input": med_in, "median_output": med_out},
               "configs": {}, "runs": []}
    rows, curve = [], []
    for cfg_name in configs:
        cfg = get_config(cfg_name)
        model = analytic_model(cfg)
        cap = saturation_rps(model, med_in, med_out)
        payload["configs"][cfg_name] = {
            "params_b": round(cfg.param_count() / 1e9, 3),
            "kv_bytes_per_token": kv_bytes_per_token(cfg),
            "model": dataclasses.asdict(model),
            "saturation_rps": round(cap, 4),
        }
        for process in processes:
            for load in loads:
                rate = cap * load
                for policy in policies:
                    summ, dt = _run_one(cfg_name, model, policy, n,
                                        rate, process, seed)
                    run = {"config": cfg_name, "policy": policy,
                           "process": process, "load": load,
                           "rate": round(rate, 4), **summ}
                    payload["runs"].append(run)
                    curve.append([cfg_name, policy, process, load,
                                  round(rate, 4), summ["attainment"],
                                  summ["goodput"], summ["mean_latency"]])
                    rows.append([
                        f"goodput_{cfg_name}_{process}_load{load:g}_"
                        f"{policy}", round(dt * 1e6, 1),
                        f"att={summ['attainment']:.3f};"
                        f"G={summ['goodput']:.5f};"
                        f"lat={summ['mean_latency']:.2f}s;"
                        f"evictions={summ['preemptions']}"])
    return rows, payload, curve


def main(quick: bool = False):
    if quick:
        rows, payload, curve = sweep(
            configs=CONFIGS[:1], policies=QUICK_POLICIES,
            loads=(0.5, 1.2), processes=("poisson",), n=300)
    else:
        rows, payload, curve = sweep()
        # non-Poisson arrival processes at the contended load, paper
        # config only: burstiness is where index/preempt spread out
        b_rows, b_payload, b_curve = sweep(
            configs=CONFIGS[:1], policies=POLICIES,
            loads=(1.2,), processes=("bursty", "diurnal"), n=2000)
        rows.extend(b_rows)
        payload["runs"].extend(b_payload["runs"])
        curve.extend(b_curve)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "BENCH_goodput.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# saved {path}")
    cpath = os.path.join(RESULTS_DIR, "goodput_attainment.csv")
    with open(cpath, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["config", "policy", "process", "load", "rate",
                    "attainment", "goodput", "mean_latency"])
        w.writerows(curve)
    print(f"# saved {cpath}")
    emit(rows, ["name", "us_per_call", "derived"], "goodput")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick)
