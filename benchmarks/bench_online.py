"""Beyond-paper: online arrivals with re-annealing vs FCFS.

Requests arrive as a Poisson process at several loads; the SLO-aware policy
re-anneals the waiting queue (with waiting-shrunk SLO budgets) at every
admission point.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import PAPER_TABLE2, SAParams
from repro.core.online import simulate_online
from repro.data.synthetic import sample_requests


def main(quick: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    n = 24 if quick else 40
    for rate in (0.5, 1.0, 2.0, 4.0):      # arrivals per second
        reqs = sample_requests(n, seed=17)
        t = 0.0
        for r in reqs:
            t += rng.exponential(1.0 / rate)
            r.arrival_time = t
            r.predicted_output_len = r.output_len
        f, dtf = timeit(simulate_online, reqs, PAPER_TABLE2, 4, "fcfs",
                        repeat=1)
        s, dts = timeit(simulate_online, reqs, PAPER_TABLE2, 4, "slo",
                        SAParams(seed=1), repeat=1)
        rows.append([f"online_rate{rate}_fcfs", round(dtf * 1e6, 1),
                     f"G={f.G:.4f};att={f.attainment:.3f}"])
        rows.append([f"online_rate{rate}_slo", round(dts * 1e6, 1),
                     f"G={s.G:.4f};att={s.attainment:.3f};"
                     f"G_vs_fcfs={s.G / f.G if f.G else 0:.3f}"])
        # multi-instance online (unified event core): 2 instances drain a
        # shared queue, each admission re-annealed
        for ninst in (2,):
            m, dtm = timeit(simulate_online, reqs, PAPER_TABLE2, 4, "slo",
                            SAParams(seed=1), num_instances=ninst, repeat=1)
            rows.append([f"online_rate{rate}_slo_x{ninst}",
                         round(dtm * 1e6, 1),
                         f"G={m.G:.4f};att={m.attainment:.3f};"
                         f"att_vs_1inst={m.attainment / s.attainment if s.attainment else 0:.3f}"])
    emit(rows, ["name", "us_per_call", "derived"], "online")
    return rows


if __name__ == "__main__":
    main()
