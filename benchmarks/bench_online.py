"""Beyond-paper: online arrivals with re-annealing vs FCFS.

Requests arrive as a Poisson process at several loads; the SLO-aware policy
re-anneals the waiting queue (with waiting-shrunk SLO budgets) at every
admission point.  API-v2 rows: ``slo-preempt`` (multi-SLO preemption —
tight arrivals may evict large-slack running requests, KV recomputed) and
the chunked-prefill execution discipline.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import PAPER_TABLE2, SAParams
from repro.core.online import simulate_online
from repro.core.slo import SLO, Request
from repro.data.synthetic import sample_requests


def _contended_mix(n: int, seed: int):
    """Long loose-e2e jobs + tight-TTFT interactive arrivals — the
    workload where preemption (not just admission ordering) is what
    saves attainment."""
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for i in range(n):
        if i % 3 == 0:      # tight interactive arrival
            r = Request(i, "chat", int(rng.integers(32, 96)),
                        SLO(ttft=0.5, tpot=0.1),
                        output_len=int(rng.integers(8, 24)))
        else:               # long batch job with a loose deadline
            r = Request(i, "code", int(rng.integers(64, 256)),
                        SLO(e2e=120.0),
                        output_len=int(rng.integers(200, 400)))
        t += rng.exponential(0.4)
        r.arrival_time = t
        r.predicted_output_len = r.output_len
        reqs.append(r)
    return reqs


def _engine_rows(quick: bool):
    """Engine-backed online rows: a real reduced-config ``Engine``
    (paged KV pool, tiny random model) drains Poisson arrivals under the
    same v2 policies the event core runs — ``fcfs`` vs ``slo-reanneal``
    vs ``slo-preempt`` — with the latency model fit from this engine's
    own profiled behaviour."""
    import jax

    from repro.core.profiler import LatencyProfiler
    from repro.engine.engine import Engine
    from repro.engine.request import RuntimeRequest
    from repro.models import ModelConfig, init_params

    cfg = ModelConfig(name="bench-tiny", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=128, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)

    def make_rts(n, seed):
        rng = np.random.default_rng(seed)
        out, t = [], 0.0
        for i in range(n):
            if i % 3 == 0:      # tight interactive arrival
                r = Request(i, "chat", int(rng.integers(8, 24)),
                            SLO(ttft=0.05, tpot=0.05),
                            output_len=int(rng.integers(3, 6)))
            else:               # long job with a loose deadline: occupies
                # a slot for dozens of decode rounds, so a tight arrival
                # stuck behind it under FCFS misses its first-token
                # deadline at any plausible CPU speed
                r = Request(i, "code", int(rng.integers(24, 56)),
                            SLO(e2e=30.0),
                            output_len=int(rng.integers(40, 60)))
            t += float(rng.exponential(0.005))
            r.arrival_time = t
            r.predicted_output_len = r.output_len
            out.append(RuntimeRequest(
                request=r,
                prompt_tokens=rng.integers(0, 128, r.input_len).astype(
                    np.int32),
                max_new_tokens=r.output_len))
        return out

    # fit the latency model from this engine's own behaviour
    prof = LatencyProfiler()
    warm = Engine(cfg, params, max_slots=2, max_seq_len=128, profiler=prof)
    warm.run_fcfs(make_rts(6, seed=0))
    model = prof.fit()

    n = 9 if quick else 15
    rows = []
    for pol in ("fcfs", "slo-reanneal", "slo-preempt"):
        eng = Engine(cfg, params, max_slots=2, max_seq_len=128)
        rts = make_rts(n, seed=1)
        out, dt = timeit(eng.run_policy, rts, pol, model=model,
                         respect_arrivals=True, repeat=1)
        att = sum(v["met"] for v in out.values()) / len(out)
        g = att * len(out) / max(sum(v["e2e"] for v in out.values()), 1e-9)
        ev = sum(v["preemptions"] for v in out.values())
        rows.append([f"engine_online_{pol}", round(dt * 1e6, 1),
                     f"G={g:.4f};att={att:.3f};evictions={ev};"
                     f"free_blocks={eng.pool.available}/{eng.pool.total}"])
    return rows


def _multiturn_rows(quick: bool):
    """Multi-turn chat rows on the engine's prefix cache: conversations
    extend their own prior turns and share system prompts, so turn-2+
    prompts alias cached pages.  Reports the token-level prefix hit
    rate and SLO attainment, prefix sharing on vs off (same arrivals,
    same model)."""
    import jax

    from repro.data.synthetic import sample_multiturn_token_requests
    from repro.engine.engine import Engine
    from repro.engine.request import RuntimeRequest
    from repro.models import ModelConfig, init_params

    cfg = ModelConfig(name="bench-tiny", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=128, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_conv = 3 if quick else 5

    def make_rts():
        pairs = sample_multiturn_token_requests(
            n_conv, turns=3, vocab=128, seed=2, system_prompt_len=48,
            n_system_prompts=2, max_new_tokens=4, think_time=0.02)
        out = []
        for r, toks in pairs:
            r.slo = SLO(ttft=0.5, tpot=0.5)
            out.append(RuntimeRequest(request=r, prompt_tokens=toks,
                                      max_new_tokens=r.output_len))
        return out

    rows = []
    for on in (True, False):
        eng = Engine(cfg, params, max_slots=4, max_seq_len=512,
                     temperature=0.0, prefix_cache=on)
        out, dt = timeit(eng.run_policy, make_rts(), "fcfs",
                         respect_arrivals=True, repeat=1)
        att = sum(v["met"] for v in out.values()) / len(out)
        stats = eng.prefix_stats()
        cached = sum(v["cached"] for v in out.values())
        rows.append([f"engine_multiturn_prefix_{'on' if on else 'off'}",
                     round(dt * 1e6, 1),
                     f"att={att:.3f};hit_rate={stats['hit_rate']:.3f};"
                     f"cached_tokens={cached};"
                     f"cow_copies={stats['cow_copies']}"])
    return rows


def main(quick: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    n = 24 if quick else 40
    for rate in (0.5, 1.0, 2.0, 4.0):      # arrivals per second
        reqs = sample_requests(n, seed=17)
        t = 0.0
        for r in reqs:
            t += rng.exponential(1.0 / rate)
            r.arrival_time = t
            r.predicted_output_len = r.output_len
        f, dtf = timeit(simulate_online, reqs, PAPER_TABLE2, 4, "fcfs",
                        repeat=1)
        s, dts = timeit(simulate_online, reqs, PAPER_TABLE2, 4, "slo",
                        SAParams(seed=1), repeat=1)
        rows.append([f"online_rate{rate}_fcfs", round(dtf * 1e6, 1),
                     f"G={f.G:.4f};att={f.attainment:.3f}"])
        rows.append([f"online_rate{rate}_slo", round(dts * 1e6, 1),
                     f"G={s.G:.4f};att={s.attainment:.3f};"
                     f"G_vs_fcfs={s.G / f.G if f.G else 0:.3f}"])
        # chunked-prefill discipline under FCFS (running decodes advance
        # between prefill chunks)
        c, dtc = timeit(simulate_online, reqs, PAPER_TABLE2, 4, "fcfs",
                        discipline="chunked:64", repeat=1)
        rows.append([f"online_rate{rate}_fcfs_chunked", round(dtc * 1e6, 1),
                     f"G={c.G:.4f};att={c.attainment:.3f};"
                     f"att_vs_stall={c.attainment / f.attainment if f.attainment else 0:.3f}"])
        # multi-instance online (unified event core): 2 instances drain a
        # shared queue, each admission re-annealed
        for ninst in (2,):
            m, dtm = timeit(simulate_online, reqs, PAPER_TABLE2, 4, "slo",
                            SAParams(seed=1), num_instances=ninst, repeat=1)
            rows.append([f"online_rate{rate}_slo_x{ninst}",
                         round(dtm * 1e6, 1),
                         f"G={m.G:.4f};att={m.attainment:.3f};"
                         f"att_vs_1inst={m.attainment / s.attainment if s.attainment else 0:.3f}"])
    # --- multi-SLO preemption (API v2) on a contended long+tight mix,
    # where evictions (KV recompute) — not just admission order — carry
    # the attainment; the evictions count in `derived` proves the
    # preemption path actually ran
    n = 18 if quick else 30
    for pol in ("fcfs", "slo-preempt"):
        reqs = _contended_mix(n, seed=3)
        s, dt = timeit(simulate_online, reqs, PAPER_TABLE2, 4, pol,
                       repeat=1)
        rows.append([f"online_contended_{pol}", round(dt * 1e6, 1),
                     f"G={s.G:.4f};att={s.attainment:.3f};"
                     f"evictions={s.n_preempted}"])
    # --- engine-backed rows: the same policies on a real reduced-config
    # Engine.run_policy (paged KV pool), not just the event core
    rows.extend(_engine_rows(quick))
    # --- multi-turn mix on the prefix cache: hit rate + attainment,
    # sharing on vs off
    rows.extend(_multiturn_rows(quick))
    emit(rows, ["name", "us_per_call", "derived"], "online")
    return rows


if __name__ == "__main__":
    main()
