"""Beyond-paper: online arrivals with re-annealing vs FCFS.

Requests arrive as a Poisson process at several loads; the SLO-aware policy
re-anneals the waiting queue (with waiting-shrunk SLO budgets) at every
admission point.  API-v2 rows: ``slo-preempt`` (multi-SLO preemption —
tight arrivals may evict large-slack running requests, KV recomputed) and
the chunked-prefill execution discipline.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import PAPER_TABLE2, SAParams
from repro.core.online import simulate_online
from repro.core.slo import SLO, Request
from repro.data.synthetic import sample_requests


def _contended_mix(n: int, seed: int):
    """Long loose-e2e jobs + tight-TTFT interactive arrivals — the
    workload where preemption (not just admission ordering) is what
    saves attainment."""
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for i in range(n):
        if i % 3 == 0:      # tight interactive arrival
            r = Request(i, "chat", int(rng.integers(32, 96)),
                        SLO(ttft=0.5, tpot=0.1),
                        output_len=int(rng.integers(8, 24)))
        else:               # long batch job with a loose deadline
            r = Request(i, "code", int(rng.integers(64, 256)),
                        SLO(e2e=120.0),
                        output_len=int(rng.integers(200, 400)))
        t += rng.exponential(0.4)
        r.arrival_time = t
        r.predicted_output_len = r.output_len
        reqs.append(r)
    return reqs


def main(quick: bool = False):
    rows = []
    rng = np.random.default_rng(0)
    n = 24 if quick else 40
    for rate in (0.5, 1.0, 2.0, 4.0):      # arrivals per second
        reqs = sample_requests(n, seed=17)
        t = 0.0
        for r in reqs:
            t += rng.exponential(1.0 / rate)
            r.arrival_time = t
            r.predicted_output_len = r.output_len
        f, dtf = timeit(simulate_online, reqs, PAPER_TABLE2, 4, "fcfs",
                        repeat=1)
        s, dts = timeit(simulate_online, reqs, PAPER_TABLE2, 4, "slo",
                        SAParams(seed=1), repeat=1)
        rows.append([f"online_rate{rate}_fcfs", round(dtf * 1e6, 1),
                     f"G={f.G:.4f};att={f.attainment:.3f}"])
        rows.append([f"online_rate{rate}_slo", round(dts * 1e6, 1),
                     f"G={s.G:.4f};att={s.attainment:.3f};"
                     f"G_vs_fcfs={s.G / f.G if f.G else 0:.3f}"])
        # chunked-prefill discipline under FCFS (running decodes advance
        # between prefill chunks)
        c, dtc = timeit(simulate_online, reqs, PAPER_TABLE2, 4, "fcfs",
                        discipline="chunked:64", repeat=1)
        rows.append([f"online_rate{rate}_fcfs_chunked", round(dtc * 1e6, 1),
                     f"G={c.G:.4f};att={c.attainment:.3f};"
                     f"att_vs_stall={c.attainment / f.attainment if f.attainment else 0:.3f}"])
        # multi-instance online (unified event core): 2 instances drain a
        # shared queue, each admission re-annealed
        for ninst in (2,):
            m, dtm = timeit(simulate_online, reqs, PAPER_TABLE2, 4, "slo",
                            SAParams(seed=1), num_instances=ninst, repeat=1)
            rows.append([f"online_rate{rate}_slo_x{ninst}",
                         round(dtm * 1e6, 1),
                         f"G={m.G:.4f};att={m.attainment:.3f};"
                         f"att_vs_1inst={m.attainment / s.attainment if s.attainment else 0:.3f}"])
    # --- multi-SLO preemption (API v2) on a contended long+tight mix,
    # where evictions (KV recompute) — not just admission order — carry
    # the attainment; the evictions count in `derived` proves the
    # preemption path actually ran
    n = 18 if quick else 30
    for pol in ("fcfs", "slo-preempt"):
        reqs = _contended_mix(n, seed=3)
        s, dt = timeit(simulate_online, reqs, PAPER_TABLE2, 4, pol,
                       repeat=1)
        rows.append([f"online_contended_{pol}", round(dt * 1e6, 1),
                     f"G={s.G:.4f};att={s.attainment:.3f};"
                     f"evictions={s.n_preempted}"])
    emit(rows, ["name", "us_per_call", "derived"], "online")
    return rows


if __name__ == "__main__":
    main()
