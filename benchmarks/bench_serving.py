"""Streaming serving-loop benchmark: wall-clock SLO attainment under
Poisson load, and the overlapped-dispatch win.

Two row families (also written to ``experiments/bench/BENCH_serving.json``
for the perf trajectory):

* ``serve_overlap_vs_sync`` — identical trace through the
  :class:`~repro.serving.ServeLoop` with overlapped one-step-lookahead
  dispatch vs the synchronous reference mode.  At temperature 0 the two
  runs must produce *identical token ids* (parity is asserted and
  recorded); the acceptance metric is measured mean time-between-tokens
  at equal token output — overlap hides host scheduling, stream
  delivery, and block accounting behind device compute.
* ``serve_rate{r}_{policy}`` — streamed Poisson load at several arrival
  rates under ≥ 2 policies (``fcfs`` and ``slo-reanneal``), reporting
  *measured* wall-clock attainment/goodput/TTFT/TBT from the token
  streams — the regime the paper's SLOs are defined in, as opposed to
  the modelled/engine-clock rows of ``bench_online``.
* ``serve_chunked_{stall,mixed}`` — head-of-line interference probe:
  three short-prompt requests are mid-decode when a long prompt
  arrives.  Under whole-prompt (stalling) prefill the newcomer's entire
  prompt occupies one tick and the running streams eat the gap as a
  time-between-tokens spike; under ``chunked:32`` the prefill rides the
  tick plan in 32-token spans alongside the decode dispatches
  (chunk-as-tick), bounding the spike by one chunk's compute.  Rows
  report max/p99/mean TBT of the *running* streams only, plus the
  fraction of ticks that mixed prefill spans with decode dispatch.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import RESULTS_DIR, emit
from repro.data.synthetic import sample_serve_workload


def _make_engine(max_slots=4, **kw):
    import jax

    from repro.engine.engine import Engine
    from repro.models import ModelConfig, init_params

    cfg = ModelConfig(name="bench-tiny", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=128, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    kw.setdefault("max_seq_len", 128)
    return Engine(cfg, params, max_slots=max_slots, **kw), cfg


def _serve(pairs, policy, overlap, model=None, max_slots=4):
    from repro.serving import ServeLoop
    eng, _ = _make_engine(max_slots)
    loop = ServeLoop(eng, policy, model=model, overlap=overlap)
    loop.start(warm_lengths=[len(p) for _, p in pairs])
    streams = loop.submit_trace([(r, p) for r, p in pairs])
    loop.serve()
    return loop, streams


def _trace(n, seed, rate, scale=1.0):
    """Fresh Request objects each run (the loop stamps them)."""
    return sample_serve_workload(n, 128, seed=seed, scale=scale,
                                 arrival_rate=rate, in_range=(8, 48),
                                 out_range=(4, 16))


def _overlap_rows(quick: bool):
    """Overlap vs sync on one trace: token parity + measured mean TBT."""
    n = 8 if quick else 16
    runs = {}
    for mode in ("sync", "overlap"):
        loop, streams = _serve(_trace(n, seed=5, rate=60.0), "fcfs",
                               overlap=(mode == "overlap"))
        s = loop.metrics.summary()
        runs[mode] = (s, [st.tokens for st in streams])
    parity = runs["sync"][1] == runs["overlap"][1]
    tok_sync = runs["sync"][0]["tokens"]
    tok_over = runs["overlap"][0]["tokens"]
    tbt_sync = runs["sync"][0]["tbt_mean"]
    tbt_over = runs["overlap"][0]["tbt_mean"]
    speedup = tbt_sync / tbt_over if tbt_over > 0 else 0.0
    payload = {
        "n_requests": n,
        "token_parity": bool(parity),
        "tokens_sync": tok_sync, "tokens_overlap": tok_over,
        "tbt_mean_sync": tbt_sync, "tbt_mean_overlap": tbt_over,
        "tbt_p90_sync": runs["sync"][0]["tbt_p90"],
        "tbt_p90_overlap": runs["overlap"][0]["tbt_p90"],
        "tbt_speedup": speedup,
        "overlap_frac": runs["overlap"][0].get("overlap_frac", 0.0),
    }
    assert parity, "overlap vs sync token ids diverged"
    assert tok_sync == tok_over, "token output not equal across modes"
    row = [["serve_overlap_vs_sync", round(tbt_over * 1e6, 2),
            f"parity={int(parity)};tok={tok_over};"
            f"tbt_sync={tbt_sync * 1e3:.3f}ms;"
            f"tbt_overlap={tbt_over * 1e3:.3f}ms;"
            f"speedup={speedup:.3f}x;"
            f"overlap_frac={payload['overlap_frac']:.2f}"]]
    return row, payload


def _rate_rows(quick: bool):
    """Wall-clock attainment vs Poisson arrival rate, ≥ 2 policies."""
    from repro.core.profiler import LatencyProfiler
    from repro.engine.request import RuntimeRequest

    # latency model fit from this engine config's own profiled behaviour
    # (slo-reanneal needs slack projections)
    prof = LatencyProfiler()
    eng, cfg = _make_engine()
    eng.profiler = prof
    eng.run_fcfs([RuntimeRequest(request=r, prompt_tokens=p,
                                 max_new_tokens=r.output_len)
                  for r, p in _trace(6, seed=0, rate=0.0)])
    model = prof.fit()

    # top rates exceed the tiny engine's ~100 req/s service capacity, and
    # the tighter non-quick SLO scale puts the queueing delay of the
    # overloaded points past the TTFT budgets — the attainment-vs-rate
    # curve shows the saturation knee (quick mode keeps loose SLOs: CI
    # machines are too noisy for a deadline-edge assertion)
    n = 10 if quick else 24
    rates = (20.0, 60.0) if quick else (10.0, 60.0, 240.0, 960.0)
    scale = 0.25 if quick else 0.05
    rows, payload = [], {}
    for rate in rates:
        for policy in ("fcfs", "slo-reanneal"):
            loop, _ = _serve(_trace(n, seed=11, rate=rate, scale=scale),
                             policy, overlap=True, model=model)
            s = loop.metrics.summary()
            key = f"rate{rate:g}_{policy}"
            payload[key] = s
            rows.append([f"serve_{key}", round(s["e2e_mean"] * 1e6, 1),
                         f"att={s['attainment']:.3f};G={s['G']:.4f};"
                         f"ttft_mean={s['ttft_mean'] * 1e3:.1f}ms;"
                         f"tbt_p90={s['tbt_p90'] * 1e3:.2f}ms;"
                         f"qdepth={s.get('queue_depth_mean', 0):.1f};"
                         f"tok_s={s['tokens_per_s']:.0f}"])
    return rows, payload


def _chunked_rows(quick: bool):
    """Running-request TBT while a long prompt prefills: stall vs mixed
    step-plans.  No hard assertion on the ratio — CI wall clocks are
    noisy — but both rows land in the JSON trajectory, and the mixed
    run must actually mix (chunk spans sharing ticks with dispatches)."""
    import jax
    import numpy as np

    from repro.core.slo import SLO
    from repro.engine.engine import Engine
    from repro.models import ModelConfig, init_params
    from repro.serving import ServeLoop

    # bench-tiny's prefill is cheaper than the loop's wall-clock noise
    # floor (~25ms GC/scheduler jitter), so the probe uses a model where
    # the whole-prompt prefill (~180ms at 448 tokens) towers over both a
    # decode round (~8ms) and one 32-token chunk (~20ms)
    cfg = ModelConfig(name="bench-probe", family="dense", num_layers=4,
                      d_model=256, num_heads=8, num_kv_heads=4, d_ff=1024,
                      vocab_size=128, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    long_len = 448                     # 14 chunks of 32
    dec_new = 32 if quick else 48      # decode budget of the runners
    results, rows = {}, []
    for mode, disc in (("stall", "stall"), ("mixed", "chunked:32")):
        rng = np.random.default_rng(17)      # identical prompts per mode
        # paged + max_seq_len past the top prefill bucket: every
        # prefill/chunk/dispatch jit the run hits is pre-warmed by
        # start(), so the rows time compute, not first-seen compiles
        eng = Engine(cfg, params, max_slots=4, max_seq_len=576,
                     paged=True, num_blocks=160)
        loop = ServeLoop(eng, "fcfs", discipline=disc)
        loop.start(warm_lengths=[16, long_len])
        # throwaway request: the first served request eats the one-time
        # eager-op compiles (sampling, RNG split, pos scatter) that
        # start()'s jit warm-up cannot reach — the measured runners
        # arrive after it drains, so their gaps time compute only
        loop.submit(rng.integers(0, 128, 16).astype(np.int32),
                    max_new_tokens=3, slo=SLO(e2e=100.0),
                    arrival_time=0.0)
        running = [loop.submit(rng.integers(0, 128, 16).astype(np.int32),
                               max_new_tokens=dec_new,
                               slo=SLO(ttft=100.0, tpot=10.0),
                               arrival_time=0.4)
                   for _ in range(3)]
        # the long prompt lands mid-stream: the runners are decoding
        # when its prefill starts, so the interference falls inside
        # their measured TBT gaps
        loop.submit(rng.integers(0, 128, long_len).astype(np.int32),
                    max_new_tokens=4, slo=SLO(e2e=100.0),
                    arrival_time=0.5)
        loop.serve()
        tbts = [g for st in running for g in st.tbts()]
        s = loop.metrics.summary()
        results[mode] = {
            "tbt_max": max(tbts) if tbts else 0.0,
            "tbt_p99": float(np.percentile(tbts, 99)) if tbts else 0.0,
            "tbt_mean": float(np.mean(tbts)) if tbts else 0.0,
            "mixed_tick_frac": s.get("mixed_tick_frac", 0.0),
            "prefill_tokens": s.get("prefill_tokens", 0),
        }
        r = results[mode]
        rows.append([f"serve_chunked_{mode}",
                     round(r["tbt_max"] * 1e6, 2),
                     f"tbt_max={r['tbt_max'] * 1e3:.3f}ms;"
                     f"tbt_p99={r['tbt_p99'] * 1e3:.3f}ms;"
                     f"tbt_mean={r['tbt_mean'] * 1e3:.3f}ms;"
                     f"mixed_frac={r['mixed_tick_frac']:.2f};"
                     f"prefill_tok={r['prefill_tokens']}"])
    assert results["mixed"]["mixed_tick_frac"] > 0.0, \
        "chunked run never mixed prefill spans with decode dispatch"
    results["tbt_max_ratio"] = (
        results["stall"]["tbt_max"] / results["mixed"]["tbt_max"]
        if results["mixed"]["tbt_max"] > 0 else 0.0)
    return rows, results


def main(quick: bool = False):
    rows, payload = _overlap_rows(quick)
    rate_rows, rate_payload = _rate_rows(quick)
    rows.extend(rate_rows)
    chunk_rows, chunk_payload = _chunked_rows(quick)
    rows.extend(chunk_rows)
    payload = {"overlap": payload, "rates": rate_payload,
               "chunked_interference": chunk_payload}
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "BENCH_serving.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# saved {path}")
    emit(rows, ["name", "us_per_call", "derived"], "serving")
    return rows


if __name__ == "__main__":
    main()
