"""Paper §4.2 / Table 2 — latency-model fit quality.

Two studies:
  * synthetic: generate samples from the Table-2 ground truth + 2% noise,
    re-fit, report prediction R² (coefficient-space recovery is ill-posed
    for near-zero coefficients like γ_d, so prediction quality is the
    meaningful metric).
  * engine: controlled (batch × length) sweep timing the REAL jitted JAX
    prefill/decode steps on CPU, median-of-3; fit; report R².
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import LinearLatencyModel, PAPER_TABLE2, fit


def _r2(y, yp):
    ss_res = np.sum((y - yp) ** 2)
    ss_tot = np.sum((y - np.mean(y)) ** 2)
    return 1 - ss_res / ss_tot if ss_tot > 0 else 1.0


def synthetic_fit_recovery():
    rng = np.random.default_rng(0)
    true = PAPER_TABLE2
    pre, dec = [], []
    for b in (1, 2, 4, 8, 16, 32):
        for l in range(100, 2000, 150):
            pre.append((b, l, true.prefill_time(b, l) * rng.normal(1, 0.02)))
            dec.append((b, l, true.per_token_decode_time(b, l)
                        * rng.normal(1, 0.02)))
    m = fit(pre, dec)
    pre = np.array(pre)
    dec = np.array(dec)
    r2p = _r2(pre[:, 2], m.prefill_time(pre[:, 0], pre[:, 1]))
    r2d = _r2(dec[:, 2], m.per_token_decode_time(dec[:, 0], dec[:, 1]))
    return m, float(r2p), float(r2d)


def engine_profile_fit(quick: bool = False):
    """Controlled sweep over the real jitted prefill/decode steps."""
    import jax
    import jax.numpy as jnp
    from repro.models import (ModelConfig, forward_decode, forward_full,
                              init_cache, init_params)

    cfg = ModelConfig(name="prof", family="dense", num_layers=4,
                      d_model=256, num_heads=8, num_kv_heads=4, d_ff=1024,
                      vocab_size=2048, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    max_len = 512 if quick else 1024

    @jax.jit
    def prefill(params, toks):
        logits, _, _ = forward_full(params, cfg, tokens=toks, last_only=True)
        return logits

    @jax.jit
    def decode(params, cache, toks):
        return forward_decode(params, cfg, tokens=toks, cache=cache)

    rng = np.random.default_rng(0)
    pre_samples, dec_samples = [], []
    batches = (1, 2, 4) if quick else (1, 2, 4, 8)
    lens = (64, 128, 256) if quick else (64, 128, 256, 512, 768)
    for b in batches:
        for l in lens:
            toks = jnp.asarray(rng.integers(0, 2048, (b, l)), jnp.int32)
            ts = []
            for _ in range(3):
                t0 = time.perf_counter()
                prefill(params, toks).block_until_ready()
                ts.append(time.perf_counter() - t0)
            pre_samples.append((b, l, float(np.median(ts[1:]))))
            cache = init_cache(cfg, b, max_len)
            cache["pos"] = jnp.full((b,), l, jnp.int32)
            tok1 = toks[:, :1]
            ts = []
            for _ in range(4):
                t0 = time.perf_counter()
                lg, cache = decode(params, cache, tok1)
                lg.block_until_ready()
                ts.append(time.perf_counter() - t0)
            dec_samples.append((b, l, float(np.median(ts[1:]))))
    m = fit(pre_samples, dec_samples)
    pre = np.array(pre_samples)
    dec = np.array(dec_samples)
    r2p = _r2(pre[:, 2], m.prefill_time(pre[:, 0], pre[:, 1]))
    r2d = _r2(dec[:, 2], m.per_token_decode_time(dec[:, 0], dec[:, 1]))
    return m, float(r2p), float(r2d), len(pre_samples), len(dec_samples)


def main(quick: bool = False):
    rows = []
    (m, r2p, r2d), dt = timeit(synthetic_fit_recovery, repeat=1)
    rows.append(["table2_synthetic_recovery", round(dt * 1e6, 1),
                 f"prefill_R2={r2p:.4f};decode_R2={r2d:.4f}"])
    (m2, r2p, r2d, np_, nd), dt = timeit(engine_profile_fit, quick, repeat=1)
    rows.append(["table2_engine_fit", round(dt * 1e6, 1),
                 f"prefill_R2={r2p:.4f};decode_R2={r2d:.4f};"
                 f"samples={np_}+{nd};alpha_p={m2.alpha_p:.3g};"
                 f"delta_d={m2.delta_d:.3g}"])
    emit(rows, ["name", "us_per_call", "derived"], "table2_fit")
    return rows


if __name__ == "__main__":
    main()
