"""Roofline analysis from the dry-run artifacts (deliverable g).

Reads ``experiments/dryrun/*.json`` (written by repro.launch.dryrun) and
derives, per (arch × shape × mesh):

  compute term    = HLO_FLOPs_per_device   / peak_FLOP/s_per_chip
  memory term     = HLO_bytes_per_device   / HBM_bw_per_chip
  collective term = collective_bytes_per_device / ICI_link_bw

(cost_analysis on the post-SPMD module reports per-device quantities, so
dividing by per-chip rates equals the global/(chips × rate) formulation.)

Also reports MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens
(inference) and the usefulness ratio MODEL_FLOPS / HLO_FLOPs, the dominant
bottleneck, and a what-would-move-it note.

TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
OUT_CSV = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "roofline.csv")
OUT_MD = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "roofline.md")

TOKENS = {
    "train_4k": 256 * 4096,
    "prefill_32k": 32 * 32768,
    "decode_32k": 128,
    "long_500k": 1,
}


def chips(mesh: str) -> int:
    n = 1
    for d in mesh.split("x"):
        n *= int(d)
    return n


def model_flops(rec) -> float:
    """Global useful FLOPs for the step (params-matmul convention)."""
    n_act = rec["active_param_count"]
    toks = TOKENS[rec["shape"]]
    mult = 6 if rec["shape"] == "train_4k" else 2
    return mult * n_act * toks


def analyze_record(rec) -> dict:
    nchips = chips(rec["mesh"])
    t_comp = rec["flops_per_device"] / PEAK_FLOPS
    # Two memory estimates. The XLA CPU backend reports bytes-accessed for
    # an UNFUSED op graph — a pessimistic upper bound for the TPU target
    # (TPU fusion keeps intermediates in VMEM/registers).  The
    # args+outputs bound (weights + caches + step I/O read/written once) is
    # the fusion-optimistic lower bound; TPU reality sits between, near the
    # lower bound for inference steps.  Dominance uses the lower bound.
    t_mem_raw = rec["bytes_accessed_per_device"] / HBM_BW
    t_mem = (rec["argument_bytes"] + rec["output_bytes"]) / HBM_BW
    coll = sum(rec["collective_bytes_per_device"].values())
    t_coll = coll / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_global = rec["flops_per_device"] * nchips
    ratio = mf / hlo_global if hlo_global else float("nan")
    # bound = the dominant term; mfu-at-roofline estimate
    note = {
        "compute": "reduce redundant/remat FLOPs or raise per-chip "
                   "utilization (fusion, larger matmul tiles)",
        "memory": "cut HBM traffic: fuse attention (flash), keep KV in "
                  "lower precision, shard the cache further",
        "collective": "reshard to remove gathers (head/seq sharding), "
                      "overlap collectives with compute, expert-parallel "
                      "all-to-all instead of weight gathers",
    }[dominant]
    return dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=t_comp, memory_s=t_mem, memory_raw_s=t_mem_raw,
        collective_s=t_coll,
        dominant=dominant, model_flops=mf, hlo_flops_global=hlo_global,
        useful_ratio=ratio, peak_gib=rec["peak_bytes"] / 2**30,
        args_gib=rec["argument_bytes"] / 2**30, note=note,
        collective_mib={k: round(v / 2**20, 1)
                        for k, v in rec["collective_bytes_per_device"].items()
                        if v},
    )


def load_all(dirname=None):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname or DRYRUN_DIR,
                                              "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def main(quick: bool = False, dirname=None, out_csv=None, out_md=None):
    global OUT_CSV, OUT_MD
    if out_csv:
        OUT_CSV = out_csv
    if out_md:
        OUT_MD = out_md
    rows = []
    mdlines = [
        "| arch | shape | mesh | compute | memory (min/raw) | collective "
        "| dominant | useful FLOP ratio | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_all(dirname):
        a = analyze_record(rec)
        rows.append(a)
        mdlines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {fmt_s(a['compute_s'])} | {fmt_s(a['memory_s'])}/"
            f"{fmt_s(a['memory_raw_s'])} "
            f"| {fmt_s(a['collective_s'])} | **{a['dominant']}** "
            f"| {a['useful_ratio']:.3f} | {a['peak_gib']:.2f} |")
    os.makedirs(os.path.dirname(OUT_CSV), exist_ok=True)
    import csv
    with open(OUT_CSV, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        for r in rows:
            w.writerow(r)
    with open(OUT_MD, "w") as f:
        f.write("\n".join(mdlines) + "\n")
    print(f"# {len(rows)} records -> {OUT_CSV}")
    for line in mdlines:
        print(line)
    return rows


def compare():
    """Baseline vs optimized comparison (dominant-term deltas)."""
    base_dir = DRYRUN_DIR
    opt_dir = os.path.join(os.path.dirname(DRYRUN_DIR), "dryrun_opt")
    base = {(r["arch"], r["shape"], r["mesh"]): analyze_record(r)
            for r in load_all(base_dir)}
    opt = {(r["arch"], r["shape"], r["mesh"]): analyze_record(r)
           for r in load_all(opt_dir)}
    lines = ["| arch | shape | mesh | coll (base→opt) | compute (base→opt) "
             "| dominant (base→opt) |",
             "|---|---|---|---|---|---|"]
    for key in sorted(base):
        if key not in opt:
            continue
        b, o = base[key], opt[key]
        lines.append(
            f"| {key[0]} | {key[1]} | {key[2]} "
            f"| {fmt_s(b['collective_s'])}→{fmt_s(o['collective_s'])} "
            f"| {fmt_s(b['compute_s'])}→{fmt_s(o['compute_s'])} "
            f"| {b['dominant']}→{o['dominant']} |")
    out = os.path.join(os.path.dirname(DRYRUN_DIR), "roofline_compare.md")
    with open(out, "w") as f:
        f.write("\n".join(lines) + "\n")
    print("\n".join(lines))
    return out


if __name__ == "__main__":
    import sys
    if "--compare" in sys.argv:
        compare()
    elif "--opt" in sys.argv:
        main(dirname=os.path.join(os.path.dirname(DRYRUN_DIR), "dryrun_opt"),
             out_csv=os.path.join(os.path.dirname(DRYRUN_DIR),
                                  "roofline_opt.csv"),
             out_md=os.path.join(os.path.dirname(DRYRUN_DIR),
                                 "roofline_opt.md"))
    else:
        main()
