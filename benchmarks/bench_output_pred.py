"""Paper Fig. 9 — impact of output-length prediction accuracy.

Planning uses actual output lengths perturbed by ±2.5/5/10% (simulating
predictors of different accuracy) vs the Gaussian profiler predictor;
execution always uses actual lengths.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import (PAPER_TABLE2, SAParams, as_arrays, priority_mapping,
                        run_fcfs_continuous, run_priority_continuous)
from repro.core.profiler import OutputLengthPredictor
from repro.data.synthetic import sample_requests

MODEL = PAPER_TABLE2


def _batches(reqs, res):
    nb = int(res.batch_id[-1]) + 1
    return [[reqs[i] for i, b in zip(res.perm, res.batch_id) if b == j]
            for j in range(nb)]


def run_with_error(reqs, max_batch, rel_err, rng, seed):
    for r in reqs:
        if rel_err is None:       # gaussian profiler predictor
            pred = OutputLengthPredictor(seed=seed)
            for q in sample_requests(200, seed=seed + 999):
                pred.observe(q.task_type, q.output_len)
            r.predicted_output_len = pred.predict(r.task_type)
        else:
            noise = rng.uniform(1 - rel_err, 1 + rel_err)
            r.predicted_output_len = max(1, int(r.output_len * noise))
    arrays = as_arrays(reqs)
    res = priority_mapping(arrays, MODEL, max_batch,
                           SAParams(seed=seed, budget_mode="per_level"))
    return run_priority_continuous(_batches(reqs, res), MODEL, max_batch)


def main(quick: bool = False):
    rows = []
    levels = [None, 0.10, 0.05, 0.025]
    names = {None: "gaussian", 0.10: "err10", 0.05: "err5", 0.025: "err2.5"}
    cases = [(10, 1), (20, 2), (40, 4)] if not quick else [(10, 1), (20, 2)]
    for n, mb in cases:
        reqs = sample_requests(n, seed=77 + n)
        base = run_fcfs_continuous(reqs, MODEL, mb)
        for lvl in levels:
            rng = np.random.default_rng(5)
            sim, dt = timeit(run_with_error, list(reqs), mb, lvl, rng,
                             seed=12, repeat=1)
            rows.append([f"fig9_n{n}_b{mb}_{names[lvl]}",
                         round(dt * 1e6, 1),
                         f"G={sim.G:.4f};att={sim.attainment:.3f};"
                         f"G_vs_fcfs={sim.G / base.G if base.G else 0:.3f}"])
    emit(rows, ["name", "us_per_call", "derived"], "fig9_output_pred")
    return rows


if __name__ == "__main__":
    main()
