"""Paper Fig. 10 — sensitivity of G to latency-predictor coefficient error.

Planning uses perturbed fitting parameters (±10/20/30% on α, β, γ, δ);
execution uses the true model.  10 requests, max batch 4 (paper setup).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import (PAPER_TABLE2, SAParams, as_arrays, priority_mapping,
                        run_priority_continuous)
from repro.data.synthetic import sample_requests

TRUE = PAPER_TABLE2


def _batches(reqs, res):
    nb = int(res.batch_id[-1]) + 1
    return [[reqs[i] for i, b in zip(res.perm, res.batch_id) if b == j]
            for j in range(nb)]


def main(quick: bool = False):
    rows = []
    reqs = sample_requests(10, seed=55)
    for r in reqs:
        r.predicted_output_len = r.output_len
    arrays = as_arrays(reqs)
    res0 = priority_mapping(arrays, TRUE, 4,
                            SAParams(seed=3, budget_mode="per_level"))
    g0 = run_priority_continuous(_batches(reqs, res0), TRUE, 4).G
    rows.append(["fig10_exact", 0.0, f"G={g0:.4f};degradation=0.0"])
    whichs = ["alpha", "beta", "gamma", "delta", "all"]
    rels = [-0.3, -0.2, -0.1, 0.1, 0.2, 0.3] if not quick else [-0.2, 0.2]
    for which in whichs:
        for rel in rels:
            pert = TRUE.perturbed(rel, which)
            res, dt = timeit(priority_mapping, arrays, pert, 4,
                             SAParams(seed=3, budget_mode="per_level"),
                             repeat=1)
            g = run_priority_continuous(_batches(reqs, res), TRUE, 4).G
            rows.append([f"fig10_{which}_{rel:+.0%}", round(dt * 1e6, 1),
                         f"G={g:.4f};degradation={(g0 - g) / g0:.4f}"])
    emit(rows, ["name", "us_per_call", "derived"], "fig10_latency_pred")
    return rows


if __name__ == "__main__":
    main()
