"""Paper Fig. 8 — effect of T0 and iter on the improvement of G.

Cases mirror the paper: (10 req, b=1), (20 req, b=2), (40 req, b=4).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import (PAPER_TABLE2, SAParams, as_arrays, evaluate,
                        fcfs_schedule, priority_mapping)
from repro.data.synthetic import sample_requests


def improvement(arrays, model, max_batch, params):
    n = len(arrays["input_len"])
    p0, b0 = fcfs_schedule(n, max_batch)
    g0 = evaluate(arrays, model, p0, b0).G
    res = priority_mapping(arrays, model, max_batch, params)
    return (res.G - g0) / g0 if g0 > 0 else 0.0


def main(quick: bool = False):
    rows = []
    cases = [(10, 1), (20, 2), (40, 4)]
    T0s = [100, 200, 500] if not quick else [100, 500]
    iters = [50, 100, 200] if not quick else [50, 100]
    for n, mb in cases:
        arrays = as_arrays(sample_requests(n, seed=31 + n))
        for T0 in T0s:
            for it in iters:
                params = SAParams(T0=T0, iters=it, seed=7,
                                  budget_mode="per_level")
                (imp), dt = timeit(improvement, arrays, PAPER_TABLE2, mb,
                                   params, repeat=1)
                rows.append([f"fig8_n{n}_b{mb}_T{T0}_i{it}",
                             round(dt * 1e6, 1),
                             f"G_improvement={imp:.4f}"])
    emit(rows, ["name", "us_per_call", "derived"], "fig8_annealing_params")
    return rows


if __name__ == "__main__":
    main()
