"""Paper Table 1 — scheduling overhead: simulated annealing vs exhaustive
search, request numbers 4/6/8/10, max batch size 1."""
from __future__ import annotations

from benchmarks.common import emit, timeit
from repro.core import (PAPER_TABLE2, SAParams, as_arrays, exhaustive_search,
                        priority_mapping)
from repro.core.annealing_jax import JaxSAConfig, priority_mapping_jax
from repro.data.synthetic import sample_requests


def main(quick: bool = False):
    rows = []
    for n in (4, 6, 8, 10):
        reqs = sample_requests(n, seed=n)
        arrays = as_arrays(reqs)
        _, t_sa = timeit(priority_mapping, arrays, PAPER_TABLE2, 1,
                         SAParams(seed=0), repeat=3)
        rows.append([f"table1_sa_n{n}", round(t_sa * 1e6, 1),
                     f"seconds={t_sa:.5f}"])
        # jitted annealer (beyond-paper): report warm time
        priority_mapping_jax(arrays, PAPER_TABLE2, 1,
                             JaxSAConfig(num_chains=4), seed=0)
        _, t_jax = timeit(priority_mapping_jax, arrays, PAPER_TABLE2, 1,
                          JaxSAConfig(num_chains=4), seed=1, repeat=3)
        rows.append([f"table1_sa_jax_n{n}", round(t_jax * 1e6, 1),
                     f"seconds={t_jax:.5f}"])
        if n <= (6 if quick else 8):
            _, t_ex = timeit(exhaustive_search, arrays, PAPER_TABLE2, 1,
                             repeat=1)
            rows.append([f"table1_exhaustive_n{n}", round(t_ex * 1e6, 1),
                         f"seconds={t_ex:.5f}"])
    emit(rows, ["name", "us_per_call", "derived"], "table1_overhead")
    return rows


if __name__ == "__main__":
    main()
