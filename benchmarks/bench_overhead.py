"""Paper Table 1 — scheduling overhead: simulated annealing vs exhaustive
search, request numbers 4/6/8/10, max batch size 1 — plus the
incremental-Δ annealers at production queue depths (N ≥ 64), where the
O(batch + n_batches) per-proposal scoring is compared against the
full-``evaluate``-per-proposal oracle path (``incremental=False``) on
BOTH backends (Python and jitted JAX), and the vmapped multi-instance
anneal is compared against a per-instance loop of single-instance
calls."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit, timeit
from repro.core import (PAPER_TABLE2, SAParams, as_arrays, exhaustive_search,
                        priority_mapping)
from repro.core.annealing_jax import (JaxSAConfig, priority_mapping_jax,
                                      priority_mapping_multi_jax)
from repro.data.synthetic import sample_requests


def _contended(reqs):
    """Tighten SLOs so the anneal cannot early-exit (forces the hot loop)."""
    for r in reqs:
        r.slo = dataclasses.replace(
            r.slo,
            e2e=r.slo.e2e * 0.2 if r.slo.e2e else None,
            ttft=r.slo.ttft * 0.02 if r.slo.ttft else None,
            tpot=r.slo.tpot * 0.5 if r.slo.tpot else None)
        r.predicted_output_len = r.output_len
    return reqs


def main(quick: bool = False):
    rows = []
    for n in (4, 6, 8, 10):
        reqs = sample_requests(n, seed=n)
        arrays = as_arrays(reqs)
        _, t_sa = timeit(priority_mapping, arrays, PAPER_TABLE2, 1,
                         SAParams(seed=0), repeat=3)
        rows.append([f"table1_sa_n{n}", round(t_sa * 1e6, 1),
                     f"seconds={t_sa:.5f}"])
        # jitted annealer (beyond-paper): report warm time
        priority_mapping_jax(arrays, PAPER_TABLE2, 1,
                             JaxSAConfig(num_chains=4), seed=0)
        _, t_jax = timeit(priority_mapping_jax, arrays, PAPER_TABLE2, 1,
                          JaxSAConfig(num_chains=4), seed=1, repeat=3)
        rows.append([f"table1_sa_jax_n{n}", round(t_jax * 1e6, 1),
                     f"seconds={t_jax:.5f}"])
        if n <= (6 if quick else 8):
            _, t_ex = timeit(exhaustive_search, arrays, PAPER_TABLE2, 1,
                             repeat=1)
            rows.append([f"table1_exhaustive_n{n}", round(t_ex * 1e6, 1),
                         f"seconds={t_ex:.5f}"])
    # --- incremental-Δ hot loop at admission-event queue depths
    for n in ((64,) if quick else (64, 128, 256)):
        reqs = _contended(sample_requests(n, seed=n))
        arrays = as_arrays(reqs)
        for mb in (1, 8):
            for budget, tag in (("global", ""), ("per_level", "_plvl")):
                p = SAParams(seed=0, budget_mode=budget)
                _, t_inc = timeit(priority_mapping, arrays, PAPER_TABLE2,
                                  mb, p, repeat=3)
                _, t_full = timeit(
                    priority_mapping, arrays, PAPER_TABLE2, mb,
                    dataclasses.replace(p, incremental=False), repeat=3)
                rows.append([f"table1_sa_n{n}_b{mb}{tag}",
                             round(t_inc * 1e6, 1),
                             f"seconds={t_inc:.5f};"
                             f"full_eval={t_full:.5f};"
                             f"speedup={t_full / t_inc:.2f}x"])
    # --- jitted annealer: incremental-Δ vs full-evaluate per proposal
    # (warm times; the proposal count is fixed by the temperature
    # schedule, so the call-time ratio IS the per-proposal ratio).
    # num_chains stays at the production default even in --quick: the
    # vmap width amortizes the fixed per-proposal dispatch overhead, and
    # the incremental/full ratio is only meaningful in that regime.
    jcfg = JaxSAConfig(num_chains=8)
    # proposals per chain are fixed by the temperature schedule (the
    # contended workloads never trigger the all-met early exit)
    props = jcfg.n_levels * jcfg.iters
    for n in ((64, 128) if quick else (64, 128, 256)):
        reqs = _contended(sample_requests(n, seed=n))
        arrays = as_arrays(reqs)
        t = {}
        for inc in (True, False):
            priority_mapping_jax(arrays, PAPER_TABLE2, 8, jcfg, seed=0,
                                 incremental=inc)          # warm the jit
            _, t[inc] = timeit(priority_mapping_jax, arrays, PAPER_TABLE2,
                               8, jcfg, seed=1, incremental=inc, repeat=3)
        rows.append([f"table1_sa_jax_inc_n{n}_b8",
                     round(t[True] * 1e6, 1),
                     f"seconds={t[True]:.5f};full_eval={t[False]:.5f};"
                     f"us_per_proposal={t[True] / props * 1e6:.2f};"
                     f"full_us_per_proposal={t[False] / props * 1e6:.2f};"
                     f"speedup={t[False] / t[True]:.2f}x"])
    # --- multi-instance vmap: I instances in ONE jitted program vs a
    # per-instance loop of single-instance calls.  The vmap's win is the
    # amortization of fixed per-proposal (dispatch + Python) overhead
    # across the fleet, so it is measured at a small chain count, where
    # that overhead dominates; on accelerator hosts extra vmap lanes are
    # close to free until the vector units saturate.
    jcfg_m = dataclasses.replace(jcfg, num_chains=2)
    n_inst, n_per = (2, 32) if quick else (4, 64)
    arrays_list = [as_arrays(_contended(sample_requests(n_per, seed=100 + i)))
                   for i in range(n_inst)]
    priority_mapping_multi_jax(arrays_list, PAPER_TABLE2, 8, jcfg_m, seed=0)
    _, t_multi = timeit(priority_mapping_multi_jax, arrays_list,
                        PAPER_TABLE2, 8, jcfg_m, seed=1, repeat=3)

    def _loop(seed):
        for i, a in enumerate(arrays_list):
            priority_mapping_jax(a, PAPER_TABLE2, 8, jcfg_m, seed=seed + i)
    _loop(0)                                               # warm the jit
    _, t_loop = timeit(_loop, 1, repeat=3)
    rows.append([f"table1_sa_jax_multi_i{n_inst}_n{n_per}",
                 round(t_multi * 1e6, 1),
                 f"seconds={t_multi:.5f};per_instance_loop={t_loop:.5f};"
                 f"speedup={t_loop / t_multi:.2f}x"])
    emit(rows, ["name", "us_per_call", "derived"], "table1_overhead")
    return rows


if __name__ == "__main__":
    main()
